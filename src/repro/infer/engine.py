"""Batched topic-inference query engine (DESIGN.md section 3).

Serving requests arrive one document at a time; TPUs want dense, fixed
shapes.  The engine bridges the two with *padding-bucket batching*: each
request's token count is rounded up to a power-of-two bucket, requests in
the same bucket are packed into fixed-size [max_batch, bucket] batches
(short batches padded with dummy rows), and one jitted ``fold_in_batch``
call serves the whole batch.  The jit cache therefore holds at most
(#buckets) compiled programs, and -- because fold-in randomness is
per-document (see infer/foldin.py) -- a request's θ is bit-identical no
matter which batch it lands in or in which order requests arrived.

Scoring implements the paper's IR smoothing use case: topic-smoothed query
likelihood (the LDA-based document model of Wei & Croft 2006),

  p(w|d) = λ · Σ_k θ_dk φ_wk  +  (1-λ) · (c(w,d) + μ p(w|C)) / (|d| + μ)

i.e. the LDA term interpolated with a Dirichlet-smoothed document language
model; documents are ranked by Σ_{w∈q} log p(w|d).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.infer.foldin import FoldInConfig, fold_in_batch, pack_docs
from repro.infer.snapshot import Snapshot, SnapshotPublisher


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32          # rows per jitted fold-in call
    min_bucket: int = 16         # smallest padding bucket (tokens)
    max_len: int = 1024          # longest supported doc (longer: truncated)
    foldin: FoldInConfig = FoldInConfig()
    smooth_lambda: float = 0.7   # weight of the LDA term in p(w|d)
    smooth_mu: float = 100.0     # Dirichlet prior mass of the doc LM


class Request(NamedTuple):
    rid: int
    tokens: np.ndarray
    seed: int


class Result(NamedTuple):
    rid: int
    theta: np.ndarray    # [K]
    version: int         # snapshot version that served this request


class QueryEngine:
    """Request queue + bucket batcher over a snapshot source.

    ``source`` is either a ``SnapshotPublisher`` (live serving: every flush
    re-acquires the latest published version) or a single ``Snapshot``
    (offline/batch scoring).
    """

    def __init__(self, source: Union[SnapshotPublisher, Snapshot],
                 ecfg: EngineConfig = EngineConfig()):
        self._source = source
        self.ecfg = ecfg
        self._queue: List[Request] = []
        self._next_rid = 0
        # snapshots recently used to serve requests, by version -- retained
        # so scoring can use the same model version that produced a θ even
        # if training has published a newer one in between
        self._recent: Dict[int, Snapshot] = {}
        # request-id -> submit time (perf_counter_ns), the start of the
        # per-request latency window the obs plane reports p50/p95/p99
        # over; entries are dropped as requests are served
        self._t_submit: Dict[int, int] = {}

    # -- snapshot plumbing ----------------------------------------------
    def snapshot(self) -> Snapshot:
        if isinstance(self._source, SnapshotPublisher):
            snap = self._source.acquire()
            if snap is None:
                raise RuntimeError("no snapshot published yet")
            return snap
        return self._source

    def _retain(self, snap: Snapshot) -> Snapshot:
        self._recent[snap.version] = snap
        while len(self._recent) > 2:          # mirror the double buffer
            self._recent.pop(min(self._recent))
        return snap

    # -- queueing --------------------------------------------------------
    def bucket_of(self, n: int) -> int:
        """Smallest power-of-two bucket >= n, clamped to ``max_len`` (docs
        longer than ``max_len`` are truncated to it)."""
        b = self.ecfg.min_bucket
        while b < n and b < self.ecfg.max_len:
            b *= 2
        return min(b, self.ecfg.max_len)

    def submit(self, tokens: Sequence[int],
               seed: Optional[int] = None) -> int:
        """Enqueue one document; returns the request id.

        ``seed`` pins the request's fold-in randomness: same (snapshot,
        tokens, seed) -> bit-identical θ regardless of batching.  Defaults
        to the request id (unique, but arrival-order dependent).
        """
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(
            rid, np.asarray(tokens, np.int32), rid if seed is None else seed))
        reg = _obs.metrics_for(self.ecfg.foldin.obs)
        if reg is not None:
            self._t_submit[rid] = time.perf_counter_ns()
            reg.gauge("serve.queue_depth").set(len(self._queue))
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- serving ---------------------------------------------------------
    def flush(self) -> Dict[int, Result]:
        """Serve every queued request; returns {rid: Result}.

        Requests are grouped into padding buckets and each bucket drained
        in fixed [max_batch, bucket] batches (dummy rows pad the last one).
        """
        snap = self._retain(self.snapshot())
        queue, self._queue = self._queue, []
        buckets: Dict[int, List[Request]] = {}
        for req in queue:
            buckets.setdefault(
                self.bucket_of(max(len(req.tokens), 1)), []).append(req)

        reg = _obs.metrics_for(self.ecfg.foldin.obs)
        tr = _obs.tracer_for(self.ecfg.foldin.obs)
        flush_sp = (tr.span("engine.flush", cat="serve",
                            requests=len(queue), version=snap.version)
                    if tr is not None else _obs.NULL_SPAN)
        out: Dict[int, Result] = {}
        mb = self.ecfg.max_batch
        for bucket in sorted(buckets):
            reqs = buckets[bucket]
            for i in range(0, len(reqs), mb):
                chunk = reqs[i:i + mb]
                batch_sp = (tr.span("engine.batch", cat="serve",
                                    bucket=bucket, occupancy=len(chunk),
                                    max_batch=mb)
                            if tr is not None else _obs.NULL_SPAN)
                # _run_batch ends on np.asarray: the batch is host-synced
                # by the time the span closes
                with batch_sp:
                    theta = self._run_batch(snap, chunk, bucket)
                t_done = time.perf_counter_ns()
                for j, req in enumerate(chunk):
                    out[req.rid] = Result(req.rid, theta[j], snap.version)
                if reg is not None:
                    reg.histogram("serve.batch_occupancy", unit="reqs") \
                        .record(len(chunk))
                    for req in chunk:
                        t0 = self._t_submit.pop(req.rid, None)
                        if t0 is not None:
                            reg.histogram("serve.request_ms").record(
                                (t_done - t0) / 1e6)
        if reg is not None:
            reg.gauge("serve.queue_depth").set(len(self._queue))
            reg.gauge("serve.snapshot_version").set(snap.version)
        flush_sp.end()
        return out

    def _run_batch(self, snap: Snapshot, chunk: List[Request],
                   bucket: int) -> np.ndarray:
        """One jitted fold-in call at the fixed [max_batch, bucket] shape."""
        mb = self.ecfg.max_batch
        docs = [r.tokens for r in chunk]
        w, valid = pack_docs(docs, bucket)
        pad = mb - len(chunk)
        if pad:
            w = np.pad(w, ((0, pad), (0, 0)))
            valid = np.pad(valid, ((0, pad), (0, 0)))
        keys = jnp.stack([jax.random.PRNGKey(r.seed) for r in chunk]
                         + [jax.random.PRNGKey(0)] * pad)
        theta = fold_in_batch(snap.model, jnp.asarray(w), jnp.asarray(valid),
                              keys, snap.cfg, self.ecfg.foldin)
        return np.asarray(theta[:len(chunk)])

    def infer(self, docs: Sequence[np.ndarray],
              seeds: Optional[Sequence[int]] = None) -> List[Result]:
        """Submit + flush convenience; results in input order."""
        rids = [self.submit(doc, None if seeds is None else seeds[i])
                for i, doc in enumerate(docs)]
        results = self.flush()
        return [results[rid] for rid in rids]

    # -- IR scoring (the paper's smoothing use case) ---------------------
    def score(self, results: Sequence[Result],
              docs: Sequence[np.ndarray],
              queries: Sequence[np.ndarray]) -> np.ndarray:
        """Topic-smoothed query-likelihood scores [num_queries, num_docs].

        Scoring uses the SAME snapshot version that produced the θs
        (carried in ``Result.version``): mixing a v1 θ with a v2 φ would
        score against an inconsistent model.  Recently served versions are
        retained by the engine; scoring θs older than that raises.
        """
        versions = {r.version for r in results}
        if len(versions) != 1:
            raise ValueError(f"results span snapshot versions {sorted(versions)}; "
                             "score each version separately")
        version = versions.pop()
        snap = self._recent.get(version)
        if snap is None:
            snap = self.snapshot()
            if snap.version != version:
                raise ValueError(
                    f"snapshot v{version} no longer available (current "
                    f"v{snap.version}); re-run fold-in before scoring")
        ld = max(max((len(d) for d in docs), default=1), 1)
        lq = max(max((len(q) for q in queries), default=1), 1)
        dw, dv = pack_docs(docs, ld)
        qw, qv = pack_docs(queries, lq)
        theta = jnp.asarray(np.stack([r.theta for r in results]))
        return np.asarray(topic_smoothed_scores(
            theta, jnp.asarray(dw), jnp.asarray(dv), jnp.asarray(qw),
            jnp.asarray(qv), snap.phi, snap.p_coll,
            self.ecfg.smooth_lambda, self.ecfg.smooth_mu))


@jax.jit
def topic_smoothed_scores(theta: jax.Array, doc_w: jax.Array,
                          doc_valid: jax.Array, q_w: jax.Array,
                          q_valid: jax.Array, phi: jax.Array,
                          p_coll: jax.Array, lam: float,
                          mu: float) -> jax.Array:
    """log p(q|d) under the λ-interpolated LDA document model.

    theta [B, K]; doc_w/doc_valid [B, Ld]; q_w/q_valid [Q, Lq];
    phi [V, K]; p_coll [V].  Returns [Q, B].
    """
    doc_len = jnp.sum(doc_valid, axis=1).astype(jnp.float32)         # [B]

    # p_lda(t|d) = Σ_k θ_dk φ_tk for every query term t: [Q, Lq, B]
    phi_q = jnp.take(phi, q_w, axis=0)                               # [Q,Lq,K]
    p_lda = jnp.einsum("qlk,bk->qlb", phi_q, theta)

    # c(t, d): occurrences of each query term in each doc's tokens
    match = (q_w[:, :, None, None] == doc_w[None, None, :, :])       # [Q,Lq,B,Ld]
    c = jnp.sum(match & doc_valid[None, None, :, :], axis=-1
                ).astype(jnp.float32)                                # [Q,Lq,B]
    p_c = jnp.take(p_coll, q_w)[:, :, None]                          # [Q,Lq,1]
    p_dir = (c + mu * p_c) / (doc_len[None, None, :] + mu)

    p = lam * p_lda + (1.0 - lam) * p_dir
    logp = jnp.log(jnp.maximum(p, 1e-30))
    return jnp.sum(jnp.where(q_valid[:, :, None], logp, 0.0), axis=1)
