"""Batched topic-inference query engine (DESIGN.md sections 3 and 14).

Serving requests arrive one document at a time; TPUs want dense, fixed
shapes.  The engine bridges the two with *padding-bucket batching*: each
request's token count is rounded up to a power-of-two bucket, requests in
the same bucket are packed into fixed-size [max_batch, bucket] batches
(short batches padded with dummy rows), and one jitted ``fold_in_batch``
call serves the whole batch.  The jit cache therefore holds at most
(#buckets) compiled programs, and -- because fold-in randomness is
per-document (see infer/foldin.py) -- a request's θ is bit-identical no
matter which batch it lands in or in which order requests arrived.

Two serving disciplines share that batching core:

  * ``QueryEngine``      -- synchronous: callers ``submit()`` then
    ``flush()`` on one thread (offline/batch scoring, tests);
  * ``ConcurrentEngine`` -- the production plane (DESIGN.md section 14):
    a thread-safe admission queue whose ``submit()`` returns a waitable
    ``Ticket``, drained by a background batcher under a dual trigger
    (bucket full OR oldest request aged past ``max_delay_ms``), with
    per-request SLO deadlines enforced by typed load-shedding
    (``DeadlineExceeded``) instead of silent queue growth.

Scoring implements the paper's IR smoothing use case: topic-smoothed query
likelihood (the LDA-based document model of Wei & Croft 2006),

  p(w|d) = λ · Σ_k θ_dk φ_wk  +  (1-λ) · (c(w,d) + μ p(w|C)) / (|d| + μ)

i.e. the LDA term interpolated with a Dirichlet-smoothed document language
model; documents are ranked by Σ_{w∈q} log p(w|d).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from functools import partial
from typing import (Deque, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.infer.foldin import FoldInConfig, fold_in_batch, pack_docs
from repro.infer.snapshot import Snapshot, SnapshotPublisher


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32          # rows per jitted fold-in call
    min_bucket: int = 16         # smallest padding bucket (tokens)
    max_len: int = 1024          # longest supported doc (longer: truncated)
    foldin: FoldInConfig = FoldInConfig()
    smooth_lambda: float = 0.7   # weight of the LDA term in p(w|d)
    smooth_mu: float = 100.0     # Dirichlet prior mass of the doc LM
    # concurrent admission (ConcurrentEngine; DESIGN.md section 14)
    max_delay_ms: float = 5.0    # oldest queued request before a forced flush
    deadline_ms: float = 0.0     # default per-request SLO (0: no deadline)


def _admit_tokens(tokens: Sequence[int], max_len: int) -> np.ndarray:
    """Admission-time canonical form of a request's tokens: int32, truncated
    to ``max_len`` (the longest supported doc; DESIGN.md section 3)."""
    tok = np.asarray(tokens, np.int32)
    return tok[:max_len] if tok.shape[0] > max_len else tok


class Request(NamedTuple):
    rid: int
    tokens: np.ndarray
    seed: int


class Result(NamedTuple):
    rid: int
    theta: np.ndarray    # [K]
    version: int         # snapshot version that served this request


class QueryEngine:
    """Request queue + bucket batcher over a snapshot source.

    ``source`` is either a ``SnapshotPublisher`` (live serving: every flush
    re-acquires the latest published version) or a single ``Snapshot``
    (offline/batch scoring).
    """

    def __init__(self, source: Union[SnapshotPublisher, Snapshot],
                 ecfg: EngineConfig = EngineConfig()):
        self._source = source
        self.ecfg = ecfg
        self._queue: List[Request] = []
        self._next_rid = 0
        # snapshots recently used to serve requests, by version -- retained
        # so scoring can use the same model version that produced a θ even
        # if training has published a newer one in between
        self._recent: Dict[int, Snapshot] = {}
        # request-id -> submit time (perf_counter_ns), the start of the
        # per-request latency window the obs plane reports p50/p95/p99
        # over; entries are dropped as requests are served
        self._t_submit: Dict[int, int] = {}

    # -- snapshot plumbing ----------------------------------------------
    def snapshot(self) -> Snapshot:
        if isinstance(self._source, SnapshotPublisher):
            snap = self._source.acquire()
            if snap is None:
                raise RuntimeError("no snapshot published yet")
            return snap
        return self._source

    def _retain(self, snap: Snapshot) -> Snapshot:
        self._recent[snap.version] = snap
        while len(self._recent) > 2:          # mirror the double buffer
            self._recent.pop(min(self._recent))
        return snap

    # -- queueing --------------------------------------------------------
    def bucket_of(self, n: int) -> int:
        """Smallest power-of-two bucket >= n, clamped to ``max_len`` (docs
        longer than ``max_len`` are truncated to it)."""
        b = self.ecfg.min_bucket
        while b < n and b < self.ecfg.max_len:
            b *= 2
        return min(b, self.ecfg.max_len)

    def submit(self, tokens: Sequence[int],
               seed: Optional[int] = None) -> int:
        """Enqueue one document; returns the request id.

        ``seed`` pins the request's fold-in randomness: same (snapshot,
        tokens, seed) -> bit-identical θ regardless of batching.  Defaults
        to the request id (unique, but arrival-order dependent).

        Documents longer than ``max_len`` are truncated *here*, at
        admission: the queue never holds more than ``max_len`` tokens per
        request, and ``_run_batch`` always receives docs that fit their
        bucket (``bucket_of`` promises exactly this).
        """
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(
            rid, _admit_tokens(tokens, self.ecfg.max_len),
            rid if seed is None else seed))
        reg = _obs.metrics_for(self.ecfg.foldin.obs)
        if reg is not None:
            self._t_submit[rid] = time.perf_counter_ns()
            reg.gauge("serve.queue_depth").set(len(self._queue))
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- serving ---------------------------------------------------------
    def flush(self) -> Dict[int, Result]:
        """Serve every queued request; returns {rid: Result}.

        Requests are grouped into padding buckets and each bucket drained
        in fixed [max_batch, bucket] batches (dummy rows pad the last one).
        """
        snap = self._retain(self.snapshot())
        queue, self._queue = self._queue, []
        buckets: Dict[int, List[Request]] = {}
        for req in queue:
            buckets.setdefault(
                self.bucket_of(max(len(req.tokens), 1)), []).append(req)

        reg = _obs.metrics_for(self.ecfg.foldin.obs)
        tr = _obs.tracer_for(self.ecfg.foldin.obs)
        flush_sp = (tr.span("engine.flush", cat="serve",
                            requests=len(queue), version=snap.version)
                    if tr is not None else _obs.NULL_SPAN)
        out: Dict[int, Result] = {}
        mb = self.ecfg.max_batch
        for bucket in sorted(buckets):
            reqs = buckets[bucket]
            for i in range(0, len(reqs), mb):
                chunk = reqs[i:i + mb]
                batch_sp = (tr.span("engine.batch", cat="serve",
                                    bucket=bucket, occupancy=len(chunk),
                                    max_batch=mb)
                            if tr is not None else _obs.NULL_SPAN)
                # _run_batch ends on np.asarray: the batch is host-synced
                # by the time the span closes
                with batch_sp:
                    theta = self._run_batch(snap, chunk, bucket)
                t_done = time.perf_counter_ns()
                for j, req in enumerate(chunk):
                    out[req.rid] = Result(req.rid, theta[j], snap.version)
                if reg is not None:
                    reg.histogram("serve.batch_occupancy", unit="reqs") \
                        .record(len(chunk))
                for req in chunk:
                    # ALWAYS pop: a request served while metrics are off
                    # (or toggled between submit and flush) must not pin
                    # its submit timestamp forever in a long-lived server
                    t0 = self._t_submit.pop(req.rid, None)
                    if t0 is not None and reg is not None:
                        reg.histogram("serve.request_ms").record(
                            (t_done - t0) / 1e6)
        if reg is not None:
            reg.gauge("serve.queue_depth").set(len(self._queue))
            reg.gauge("serve.snapshot_version").set(snap.version)
        flush_sp.end()
        return out

    def _run_batch(self, snap: Snapshot, chunk: List[Request],
                   bucket: int) -> np.ndarray:
        """One jitted fold-in call at the fixed [max_batch, bucket] shape."""
        mb = self.ecfg.max_batch
        docs = [r.tokens for r in chunk]
        w, valid = pack_docs(docs, bucket)
        pad = mb - len(chunk)
        if pad:
            w = np.pad(w, ((0, pad), (0, 0)))
            valid = np.pad(valid, ((0, pad), (0, 0)))
        keys = jnp.stack([jax.random.PRNGKey(r.seed) for r in chunk]
                         + [jax.random.PRNGKey(0)] * pad)
        theta = fold_in_batch(snap.model, jnp.asarray(w), jnp.asarray(valid),
                              keys, snap.cfg, self.ecfg.foldin)
        return np.asarray(theta[:len(chunk)])

    def infer(self, docs: Sequence[np.ndarray],
              seeds: Optional[Sequence[int]] = None) -> List[Result]:
        """Submit + flush convenience; results in input order."""
        rids = [self.submit(doc, None if seeds is None else seeds[i])
                for i, doc in enumerate(docs)]
        results = self.flush()
        return [results[rid] for rid in rids]

    # -- IR scoring (the paper's smoothing use case) ---------------------
    def score(self, results: Sequence[Result],
              docs: Sequence[np.ndarray],
              queries: Sequence[np.ndarray]) -> np.ndarray:
        """Topic-smoothed query-likelihood scores [num_queries, num_docs].

        Scoring uses the SAME snapshot version that produced the θs
        (carried in ``Result.version``): mixing a v1 θ with a v2 φ would
        score against an inconsistent model.  Recently served versions are
        retained by the engine; scoring θs older than that raises.

        Pack lengths are rounded up to the engine's power-of-two buckets
        (``bucket_of``): packing at the exact max length would compile a
        fresh ``topic_smoothed_scores`` program for every distinct
        ``(ld, lq)`` pair -- unbounded retrace churn in a long-lived
        server.  Bucketed, the jit cache is bounded by #buckets².
        """
        versions = {r.version for r in results}
        if len(versions) != 1:
            raise ValueError(f"results span snapshot versions {sorted(versions)}; "
                             "score each version separately")
        version = versions.pop()
        snap = self._recent.get(version)
        if snap is None:
            snap = self.snapshot()
            if snap.version != version:
                raise ValueError(
                    f"snapshot v{version} no longer available (current "
                    f"v{snap.version}); re-run fold-in before scoring")
        ld = self.bucket_of(max(max((len(d) for d in docs), default=1), 1))
        lq = self.bucket_of(max(max((len(q) for q in queries), default=1), 1))
        dw, dv = pack_docs(docs, ld)
        qw, qv = pack_docs(queries, lq)
        theta = jnp.asarray(np.stack([r.theta for r in results]))
        return np.asarray(topic_smoothed_scores(
            theta, jnp.asarray(dw), jnp.asarray(dv), jnp.asarray(qw),
            jnp.asarray(qv), snap.phi, snap.p_coll,
            self.ecfg.smooth_lambda, self.ecfg.smooth_mu))


@jax.jit
def topic_smoothed_scores(theta: jax.Array, doc_w: jax.Array,
                          doc_valid: jax.Array, q_w: jax.Array,
                          q_valid: jax.Array, phi: jax.Array,
                          p_coll: jax.Array, lam: float,
                          mu: float) -> jax.Array:
    """log p(q|d) under the λ-interpolated LDA document model.

    theta [B, K]; doc_w/doc_valid [B, Ld]; q_w/q_valid [Q, Lq];
    phi [V, K]; p_coll [V].  Returns [Q, B].
    """
    doc_len = jnp.sum(doc_valid, axis=1).astype(jnp.float32)         # [B]

    # p_lda(t|d) = Σ_k θ_dk φ_tk for every query term t: [Q, Lq, B]
    phi_q = jnp.take(phi, q_w, axis=0)                               # [Q,Lq,K]
    p_lda = jnp.einsum("qlk,bk->qlb", phi_q, theta)

    # c(t, d): occurrences of each query term in each doc's tokens
    match = (q_w[:, :, None, None] == doc_w[None, None, :, :])       # [Q,Lq,B,Ld]
    c = jnp.sum(match & doc_valid[None, None, :, :], axis=-1
                ).astype(jnp.float32)                                # [Q,Lq,B]
    p_c = jnp.take(p_coll, q_w)[:, :, None]                          # [Q,Lq,1]
    p_dir = (c + mu * p_c) / (doc_len[None, None, :] + mu)

    p = lam * p_lda + (1.0 - lam) * p_dir
    logp = jnp.log(jnp.maximum(p, 1e-30))
    return jnp.sum(jnp.where(q_valid[:, :, None], logp, 0.0), axis=1)


# ---------------------------------------------------------------------------
# Concurrent serving plane (DESIGN.md section 14).
# ---------------------------------------------------------------------------

class DeadlineExceeded(RuntimeError):
    """Typed load-shed: the request aged past its SLO deadline while
    queued, so the batcher refused it instead of serving it late.

    Raised out of ``Ticket.result()`` on the submitter's thread; carries
    the request id, how long it sat queued, and the deadline it missed.
    Shedding is the back-pressure mechanism: under overload the queue
    stays bounded and late requests fail *loudly and typed* rather than
    silently stretching every other request's latency.
    """

    def __init__(self, rid: int, waited_ms: float, deadline_ms: float):
        super().__init__(
            f"request {rid} shed after {waited_ms:.2f} ms queued "
            f"(deadline {deadline_ms:.2f} ms)")
        self.rid = rid
        self.waited_ms = waited_ms
        self.deadline_ms = deadline_ms


class Ticket:
    """Waitable handle for one admitted request.

    The submitter blocks on ``result()`` until the batcher either serves
    the request (returns its ``Result``) or sheds it (raises
    ``DeadlineExceeded``); any internal batch failure is re-raised as-is.
    A ticket completes exactly once, always from the batcher thread.
    """

    __slots__ = ("rid", "_done", "_result", "_error")

    def __init__(self, rid: int):
        self.rid = rid
        self._done = threading.Event()
        self._result: Optional[Result] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Result:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served within "
                               f"{timeout}s (still queued or in flight)")
        if self._error is not None:
            raise self._error
        return self._result

    # -- batcher side (exactly-once completion) --------------------------
    def _complete(self, result: Result) -> None:
        self._result = result
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._done.set()


class _Admitted(NamedTuple):
    """One queued request: ticket + request + its admission bookkeeping."""
    ticket: Ticket
    request: Request
    bucket: int
    t_submit_ns: int
    t_deadline_ns: Optional[int]   # absolute shed time (None: no deadline)


class ConcurrentEngine:
    """Thread-safe admission queue + latency-bounded background batcher.

    Production model servers get throughput from *dynamic batching over
    concurrent clients*: many independent submitters, one batcher thread
    assembling dense [max_batch, bucket] fold-in calls.  The assembly
    discipline is the classic dual trigger:

      * **full**    -- a padding bucket reaches ``max_batch`` queued
        requests: flush immediately (throughput trigger);
      * **timeout** -- the oldest queued request has waited
        ``max_delay_ms``: flush its bucket even part-full (latency
        trigger -- no request waits unboundedly for co-batchees);
      * **drain**   -- ``close(drain=True)``: flush the remainder.

    Requests whose SLO deadline passes before their batch is assembled
    are *shed*: their ticket raises ``DeadlineExceeded`` and the
    ``serve.shed`` counter increments -- typed back-pressure instead of
    silent queue growth.  Once a request makes it into a batch it is
    always served, even if the device work completes past its deadline
    (the deadline bounds *queueing*, the batcher never wastes done work).

    θ determinism is inherited from the fold-in contract: per-request θ
    is a pure function of (snapshot, tokens, seed), so however the
    dynamic batches slice the arrival stream, a pinned request is
    bit-identical to its synchronous ``QueryEngine`` serving.  Each batch
    re-acquires the latest published snapshot, which is what makes
    zero-downtime live refresh free: a publisher flip between two batches
    simply routes the next batch to the new version.
    """

    def __init__(self, engine: QueryEngine,
                 max_delay_ms: Optional[float] = None,
                 deadline_ms: Optional[float] = None):
        self.engine = engine
        ecfg = engine.ecfg
        self.max_delay_ms = (ecfg.max_delay_ms if max_delay_ms is None
                             else float(max_delay_ms))
        self.deadline_ms = (ecfg.deadline_ms if deadline_ms is None
                            else float(deadline_ms))
        self._cond = threading.Condition()
        self._buckets: Dict[int, Deque[_Admitted]] = {}
        self._pending = 0
        self._next_rid = 0
        self._stop = False
        self._drain = True
        self._thread: Optional[threading.Thread] = None
        # lifetime outcome counters (mirrored into the obs registry when
        # one is installed; kept here so callers can assert without obs)
        self.served = 0
        self.shed = 0
        self.failed = 0

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "ConcurrentEngine":
        with self._cond:
            if self._thread is not None:
                raise RuntimeError("batcher already running")
            self._stop = False
            self._thread = threading.Thread(
                target=self._serve_loop, name="repro-serve-batcher",
                daemon=True)
            self._thread.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Stop the batcher.  ``drain=True`` serves everything still
        queued first; ``drain=False`` fails the remainder (each pending
        ticket raises RuntimeError)."""
        with self._cond:
            if self._thread is None:
                return
            self._stop = True
            self._drain = drain
            self._cond.notify_all()
            thread = self._thread
        thread.join()
        with self._cond:
            self._thread = None

    def __enter__(self) -> "ConcurrentEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def pending(self) -> int:
        with self._cond:
            return self._pending

    # -- admission (any thread) ------------------------------------------
    def submit(self, tokens: Sequence[int], seed: Optional[int] = None,
               deadline_ms: Optional[float] = None) -> Ticket:
        """Admit one document; returns a waitable ``Ticket``.

        ``seed`` pins fold-in randomness exactly as in
        ``QueryEngine.submit``; ``deadline_ms`` overrides the engine-wide
        SLO for this request (0 disables).  Tokens beyond ``max_len`` are
        truncated at admission.
        """
        tok = _admit_tokens(tokens, self.engine.ecfg.max_len)
        dl = self.deadline_ms if deadline_ms is None else float(deadline_ms)
        now = time.perf_counter_ns()
        with self._cond:
            if self._thread is None or self._stop:
                raise RuntimeError("serving is not running (start() first)")
            rid = self._next_rid
            self._next_rid += 1
            ticket = Ticket(rid)
            entry = _Admitted(
                ticket, Request(rid, tok, rid if seed is None else seed),
                self.engine.bucket_of(max(tok.shape[0], 1)), now,
                now + int(dl * 1e6) if dl > 0 else None)
            self._buckets.setdefault(entry.bucket,
                                     collections.deque()).append(entry)
            self._pending += 1
            depth = self._pending
            self._cond.notify()
        reg = _obs.metrics_for(self.engine.ecfg.foldin.obs)
        if reg is not None:
            reg.gauge("serve.queue_depth").set(depth)
        return ticket

    # -- batcher thread ---------------------------------------------------
    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                now = time.perf_counter_ns()
                expired = self._pop_expired(now)
                batch, trigger = self._assemble(now)
                done = self._stop and batch is None and self._pending == 0
                if batch is None and not expired and not done:
                    self._cond.wait(timeout=self._wait_s(now))
            for entry in expired:
                self._shed_one(entry)
            if batch is not None:
                self._serve(batch, trigger)
            elif done and not expired:
                return

    def _pop_expired(self, now_ns: int) -> List[_Admitted]:
        """Remove every queued request whose deadline has passed (called
        under the lock; tickets are failed outside it)."""
        out: List[_Admitted] = []
        for bucket, dq in self._buckets.items():
            if any(e.t_deadline_ns is not None and e.t_deadline_ns <= now_ns
                   for e in dq):
                keep = collections.deque()
                for e in dq:
                    if (e.t_deadline_ns is not None
                            and e.t_deadline_ns <= now_ns):
                        out.append(e)
                    else:
                        keep.append(e)
                self._buckets[bucket] = keep
        self._pending -= len(out)
        return out

    def _assemble(self, now_ns: int) -> Tuple[Optional[List[_Admitted]],
                                              Optional[str]]:
        """Dual-trigger batch assembly (called under the lock).

        Priority: any full bucket first (throughput), else the bucket
        whose head has aged past ``max_delay_ms`` (latency), else -- when
        stopping with ``drain`` -- the oldest bucket outright.
        """
        mb = self.engine.ecfg.max_batch
        aged_ns = int(self.max_delay_ms * 1e6)
        oldest_bucket, oldest_t = None, None
        for bucket in sorted(self._buckets):
            dq = self._buckets[bucket]
            if not dq:
                continue
            if len(dq) >= mb:
                return self._take(bucket, mb), "full"
            if oldest_t is None or dq[0].t_submit_ns < oldest_t:
                oldest_bucket, oldest_t = bucket, dq[0].t_submit_ns
        if oldest_bucket is None:
            return None, None
        if now_ns - oldest_t >= aged_ns:
            return self._take(oldest_bucket, mb), "timeout"
        if self._stop:
            if not self._drain:
                for bucket in list(self._buckets):
                    for e in self._take(bucket, self._pending + mb):
                        e.ticket._fail(RuntimeError(
                            f"request {e.request.rid} dropped: serving "
                            f"stopped without drain"))
                        self.failed += 1
                return None, None
            return self._take(oldest_bucket, mb), "drain"
        return None, None

    def _take(self, bucket: int, n: int) -> List[_Admitted]:
        dq = self._buckets[bucket]
        out = [dq.popleft() for _ in range(min(n, len(dq)))]
        self._pending -= len(out)
        return out

    def _wait_s(self, now_ns: int) -> Optional[float]:
        """Sleep until the next time-based trigger could fire: the oldest
        head ageing out, or the earliest queued deadline (None: idle)."""
        next_ns = None
        aged_ns = int(self.max_delay_ms * 1e6)
        for dq in self._buckets.values():
            for e in dq:
                cands = [e.t_submit_ns + aged_ns]
                if e.t_deadline_ns is not None:
                    cands.append(e.t_deadline_ns)
                t = min(cands)
                if next_ns is None or t < next_ns:
                    next_ns = t
        if next_ns is None:
            return None
        return max((next_ns - now_ns) / 1e9, 0.0)

    def _shed_one(self, entry: _Admitted) -> None:
        now = time.perf_counter_ns()
        waited_ms = (now - entry.t_submit_ns) / 1e6
        deadline_ms = (entry.t_deadline_ns - entry.t_submit_ns) / 1e6
        entry.ticket._fail(DeadlineExceeded(entry.request.rid, waited_ms,
                                            deadline_ms))
        self.shed += 1
        reg = _obs.metrics_for(self.engine.ecfg.foldin.obs)
        if reg is not None:
            reg.counter("serve.shed").inc()

    def _serve(self, batch: List[_Admitted], trigger: str) -> None:
        engine = self.engine
        reqs = [e.request for e in batch]
        bucket = batch[0].bucket
        reg = _obs.metrics_for(engine.ecfg.foldin.obs)
        tr = _obs.tracer_for(engine.ecfg.foldin.obs)
        try:
            snap = engine._retain(engine.snapshot())
            sp = (tr.span("engine.batch", cat="serve", bucket=bucket,
                          occupancy=len(batch), trigger=trigger,
                          max_batch=engine.ecfg.max_batch)
                  if tr is not None else _obs.NULL_SPAN)
            with sp:
                theta = engine._run_batch(snap, reqs, bucket)
        except BaseException as exc:   # noqa: BLE001 -- fail the tickets,
            for e in batch:            # never wedge their submitters
                e.ticket._fail(exc)
            self.failed += len(batch)
            if reg is not None:
                reg.counter("serve.batch_errors").inc(len(batch))
            return
        t_done = time.perf_counter_ns()
        for j, e in enumerate(batch):
            e.ticket._complete(Result(e.request.rid, theta[j], snap.version))
        self.served += len(batch)
        if reg is not None:
            reg.counter(f"serve.batch_trigger.{trigger}").inc()
            reg.histogram("serve.batch_occupancy", unit="reqs") \
                .record(len(batch))
            for e in batch:
                reg.histogram("serve.request_ms").record(
                    (t_done - e.t_submit_ns) / 1e6)
            reg.gauge("serve.snapshot_version").set(snap.version)
            src = engine._source
            if isinstance(src, SnapshotPublisher):
                # bounded staleness, made measurable: how many published
                # versions the batch just served lags the newest
                reg.gauge("serve.version_lag").set(src.version
                                                   - snap.version)
