"""Double-buffered model snapshot publication (DESIGN.md section 3).

The serving-side analogue of the paper's asynchronous pull (section 2.3):
training keeps pushing deltas into the live count tables while serving
reads a *consistent, bounded-stale* model.  Consistency comes from
immutability -- a ``Snapshot`` is a frozen value ``(n_wk, n_k, alias
tables, φ)`` built atomically from one training state -- and bounded
staleness from the publisher: readers always see the latest *published*
version, which lags the training sweep by at most one publication
interval.

Double buffering: the publisher owns two snapshot slots and always builds
the next snapshot into the slot readers are NOT holding, then flips the
active index in a single reference store.  Readers (``acquire``) never
block and never observe a half-built snapshot; in-flight requests keep the
version they started with until they drop it.  The version counter is
strictly monotonic (asserted in tests).

The publisher is a *read-only consumer* of the parameter-server client
API: ``publish_view`` takes a ``ps.ReadOnlyView`` of the training
``MatrixHandle`` -- pulls only, pushes are a type error -- so the
training-to-serving handoff is the same pull primitive as everything
else (paper section 2.3), never a private peek at storage.
"""
from __future__ import annotations

import functools
import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro import ps
from repro.core import lightlda as lda
from repro.core import perplexity as ppl


class Snapshot(NamedTuple):
    """One immutable published model version.

    ``model`` carries the frozen counts + alias tables the fold-in sampler
    consumes; ``phi`` is the smoothed topic-word matrix used for scoring
    (φ_wk = (n_wk+β)/(n_k+Vβ)); ``p_coll`` is the collection unigram model
    p(w|C) used by query-likelihood smoothing.
    """

    version: int
    model: lda.FrozenModel
    phi: jax.Array        # [V, K] float32
    p_coll: jax.Array     # [V]    float32, collection language model
    cfg: lda.LDAConfig

    @property
    def theta_prior(self) -> float:
        return self.cfg.alpha


@functools.lru_cache(maxsize=16)
def _snapshot_builder(cfg: lda.LDAConfig, use_kernels: bool):
    """One jit-compiled snapshot pipeline per ``(cfg, kernel-path)``.

    Publication used to re-trace φ + alias + p(w|C) eagerly op by op on
    every publish -- a ~1.4 s stall per version that was almost entirely
    XLA retracing, not math.  Caching the jitted builder on the hashable
    ``LDAConfig`` makes the first publish pay compilation once and every
    subsequent publish of the same geometry run the compiled program
    (~ms).  ``use_kernels`` routes the alias build through the Pallas
    kernel (``cfg.use_kernels``; same induced pmf, see
    ``lightlda.freeze_model``).
    """

    def build(nwk_dense, nk):
        nwk_f = nwk_dense.astype(jnp.float32)
        nk_f = nk.astype(jnp.float32)
        phi = ppl.phi_from_counts(nwk_f, nk_f, cfg.beta)
        model = lda.freeze_model(nwk_f, nk_f, cfg, weights=phi,
                                 use_kernels=use_kernels,
                                 interpret=cfg.kernel_interpret)
        freq = model.nwk.sum(axis=1)
        p_coll = (freq + 1.0) / (freq.sum() + cfg.V)  # add-one smoothed
        return model, phi, p_coll

    return jax.jit(build)


def build_snapshot(nwk_dense: jax.Array, nk: jax.Array,
                   cfg: lda.LDAConfig, version: int) -> Snapshot:
    """Freeze dense counts into a ``Snapshot`` (alias tables + φ + p(w|C)).

    φ doubles as the word-proposal weights (same smoothed matrix), so it
    is computed once and shared with the alias build.  The whole freeze
    runs as one cached jitted program (``_snapshot_builder``), so steady-
    state publication is device-bound, not retrace-bound."""
    builder = _snapshot_builder(cfg, bool(cfg.use_kernels))
    model, phi, p_coll = builder(jnp.asarray(nwk_dense), jnp.asarray(nk))
    return Snapshot(version, model, phi, p_coll, cfg)


class SnapshotPublisher:
    """Training-to-serving handoff with monotonic versions.

    ``publish`` is called from the training loop (typically every few
    sweeps); ``acquire`` from any number of serving threads.  Publication
    cost is the O(V*K) alias build -- amortised over every request served
    from that snapshot, exactly the trade the paper makes with its stale
    pulled working sets.
    """

    def __init__(self, cfg: lda.LDAConfig):
        self.cfg = cfg
        self._slots: list = [None, None]
        self._active: int = -1          # -1: nothing published yet
        self._version: int = 0
        self._publish_lock = threading.Lock()

    # -- training side ---------------------------------------------------
    def publish(self, nwk_dense: jax.Array, nk: jax.Array) -> Snapshot:
        """Build and atomically publish the next version from dense counts.

        Obs spans break the publication cost into its phases --
        ``snapshot.build`` (φ + alias tables + p(w|C) dispatch),
        ``snapshot.sync`` (awaiting the device work; this block was always
        here, the span just names it) and ``snapshot.swap`` (the reference
        flip) -- the breakdown of the ~seconds-scale publish cost the
        ISSUE calls out.  Purely observational: published values are
        identical with tracing on or off.
        """
        with self._publish_lock:
            target = 1 - self._active if self._active >= 0 else 0
            version = self._version + 1
            with _obs.span("snapshot.build", cat="snapshot",
                           version=version):
                snap = build_snapshot(jnp.asarray(nwk_dense),
                                      jnp.asarray(nk), self.cfg, version)
            with _obs.span("snapshot.sync", cat="snapshot",
                           version=version):
                jax.block_until_ready(snap.model.aprob)  # built pre-flip
            with _obs.span("snapshot.swap", cat="snapshot",
                           version=version):
                # Order matters for lock-free readers: the slot is filled
                # first, the active index flips second, and the version
                # counter advances LAST.  A reader that observes
                # ``publisher.version == N`` is therefore guaranteed that
                # ``acquire()`` already returns version N (or newer) --
                # the property the serving version-lag gauge and any
                # refresh logic keyed on ``version`` rely on.  (With the
                # old version-before-flip order, a concurrent reader
                # could see version N while still acquiring N-1.)
                self._slots[target] = snap
                self._active = target    # the flip: one reference store
                self._version = version
        reg = _obs.metrics_registry()
        if reg is not None:
            reg.gauge("snapshot.version").set(version)
        return snap

    def publish_view(self, view: "ps.ReadOnlyView",
                     nk: "ps.VectorHandle") -> Snapshot:
        """Publish from a read-only snapshot view of the training handles
        (the sanctioned serving-side read: pull, never push).

        Storage-agnostic: for a tiered handle (``ps.TieredMatrixHandle``)
        ``to_dense`` composes hot device rows over the memmap cold tier,
        so the published model is the same bitwise table a single-tier
        handle would yield.  When the view is tiered the pull span is
        annotated with the tier geometry and hit rate at publish time.
        """
        with _obs.span("snapshot.pull", cat="snapshot") as sp:
            stats_fn = getattr(view.handle, "tier_stats", None)
            if stats_fn is not None:
                sp.set(tier_hot_rows=view.handle.tier.hot_rows,
                       tier_hit_rate=round(stats_fn().hit_rate(), 4))
            dense = sp.sync_on(view.to_dense())
            nk_val = nk.pull_all().result()
        return self.publish(dense, nk_val)

    def publish_state(self, state: lda.SamplerState) -> Snapshot:
        """Publish straight from a training ``SamplerState``."""
        return self.publish_view(state.nwk.read_view(), state.nk)

    # -- serving side ----------------------------------------------------
    def acquire(self) -> Optional[Snapshot]:
        """Latest published snapshot (never blocks; None before the first
        publish).  The returned value is immutable -- holding it pins that
        version for as long as the caller needs it."""
        active = self._active             # single read: no torn state
        return self._slots[active] if active >= 0 else None

    @property
    def version(self) -> int:
        return self._version
