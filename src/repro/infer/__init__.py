"""Topic inference serving subsystem (DESIGN.md section 3).

Turns a trained LDA model into a serving endpoint:

  foldin    -- batched, jitted MH fold-in of unseen documents against a
               frozen (n_wk, n_k) snapshot (amortised-O(1) sampling via the
               snapshot's alias tables);
  snapshot  -- double-buffered snapshot publication from the training sweep
               to the inference path (monotonic versions, bounded staleness);
  engine    -- request queue with padding-bucket batching returning per-doc
               topic vectors θ plus topic-smoothed query-likelihood scores;
               synchronous (``QueryEngine``) and concurrent
               (``ConcurrentEngine``: admission tickets, dual-trigger
               dynamic batching, deadline load-shedding; DESIGN.md
               section 14).
"""
from repro.infer.foldin import FoldInConfig, fold_in_batch, pack_docs
from repro.infer.snapshot import Snapshot, SnapshotPublisher
from repro.infer.engine import (ConcurrentEngine, DeadlineExceeded,
                                EngineConfig, QueryEngine, Ticket)

__all__ = [
    "FoldInConfig", "fold_in_batch", "pack_docs",
    "Snapshot", "SnapshotPublisher",
    "ConcurrentEngine", "DeadlineExceeded", "EngineConfig", "QueryEngine",
    "Ticket",
]
