"""Topic inference serving subsystem (DESIGN.md section 3).

Turns a trained LDA model into a serving endpoint:

  foldin    -- batched, jitted MH fold-in of unseen documents against a
               frozen (n_wk, n_k) snapshot (amortised-O(1) sampling via the
               snapshot's alias tables);
  snapshot  -- double-buffered snapshot publication from the training sweep
               to the inference path (monotonic versions, bounded staleness);
  engine    -- request queue with padding-bucket batching returning per-doc
               topic vectors θ plus topic-smoothed query-likelihood scores.
"""
from repro.infer.foldin import FoldInConfig, fold_in_batch, pack_docs
from repro.infer.snapshot import Snapshot, SnapshotPublisher
from repro.infer.engine import EngineConfig, QueryEngine

__all__ = [
    "FoldInConfig", "fold_in_batch", "pack_docs",
    "Snapshot", "SnapshotPublisher",
    "EngineConfig", "QueryEngine",
]
