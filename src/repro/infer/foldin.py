"""Batched fold-in inference against a frozen model (DESIGN.md section 3).

Fold-in estimates θ_d for *unseen* documents by Gibbs/MH-sampling their
topic assignments with the model counts (n_wk, n_k) frozen -- the serving
counterpart of the training sweep in core/lightlda.py, and the sampler
behind the paper's IR use cases (retrieval smoothing, feedback).

The chain reuses LightLDA's O(1) machinery wholesale: because the word
proposal q_w(k) ∝ (n_wk+β)/(n_k+Vβ) depends only on the frozen counts, the
Vose alias tables are built ONCE per snapshot (``lightlda.freeze_model``)
and every request afterwards samples in amortised O(1) per token.  The only
semantic difference from training is the -dw correction: an unseen
document's tokens were never counted into n_wk/n_k, so the exclusion
applies to the local n_dk only (``frozen=True`` in ``mh_chain`` and the
Pallas kernel).

Layout: documents are packed into a dense [B, L] batch (tokens left-packed
per row, right-padded with ``valid=False``).  All randomness is derived
from a *per-document* PRNG key, and every operation in the sweep is
row-wise -- no cross-document reductions -- so a document's θ is a pure
function of (snapshot, tokens, its key, L).  The query engine relies on
this: results are bit-identical no matter how requests are batched
together, which is what makes padding-bucket batching transparent to
callers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lightlda as lda
from repro.obs import ObsConfig


@dataclasses.dataclass(frozen=True)
class FoldInConfig:
    """Fold-in chain schedule.

    ``num_sweeps`` full passes over each document's tokens; θ is estimated
    from the average n_dk of the post-``burnin`` sweeps (a Rao-Blackwellised
    point estimate, lower variance than the last sample alone).

    ``obs`` is the serving-side telemetry tri-state (None: inherit the
    installed session; ``ObsConfig(enabled=False)``: suppress the
    engine's spans/metrics locally).  ``ObsConfig`` is frozen and
    hashable, so this config remains a valid jit static argname.
    """

    num_sweeps: int = 30
    burnin: int = 10
    use_kernels: bool = False     # Pallas inference kernel (frozen=True)
    kernel_interpret: Optional[bool] = None  # None: ops.default_interpret
    obs: Optional[ObsConfig] = None

    def __post_init__(self):
        assert 0 <= self.burnin < self.num_sweeps, (self.burnin,
                                                    self.num_sweeps)


def pack_docs(docs: Sequence[np.ndarray], length: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Pack token-id lists into the dense [B, L] fold-in layout.

    Tokens are left-packed and right-padded (the layout ``fold_in_batch``
    requires); docs longer than ``length`` are truncated.
    """
    b = len(docs)
    w = np.zeros((b, length), np.int32)
    valid = np.zeros((b, length), bool)
    for i, doc in enumerate(docs):
        n = min(len(doc), length)
        w[i, :n] = np.asarray(doc[:n], np.int32)
        valid[i, :n] = True
    return w, valid


def _doc_randoms(key: jax.Array, z_row: jax.Array, nd: jax.Array,
                 cfg: lda.LDAConfig) -> Tuple[jax.Array, jax.Array,
                                              jax.Array, jax.Array]:
    """Pre-draw one sweep's MH randomness for a single document row.

    Mirrors ``lightlda.draw_mh_randoms`` + ``make_doc_draw`` but scoped to
    one [L] row: the doc proposal q_d(k) ∝ n_dk+α is drawn O(1) by picking
    a uniformly random token of the row's left-packed prefix (the n_dk/N_d
    part) or a uniform topic (the α-branch).  Returns [mh_steps, L] arrays.
    """
    l = z_row.shape[0]
    shape = (cfg.mh_steps, l)
    kw, kwa, kd, kda = jax.random.split(key, 4)
    k1, k2, k3 = jax.random.split(kd, 3)
    ndf = jnp.maximum(nd.astype(jnp.float32), 1.0)
    pos = (jax.random.uniform(k1, shape) * ndf).astype(jnp.int32)
    pos = jnp.minimum(pos, jnp.maximum(nd - 1, 0))
    z_tok = jnp.take(z_row, pos)
    z_unif = jax.random.randint(k2, shape, 0, cfg.K, dtype=jnp.int32)
    use_tok = (jax.random.uniform(k3, shape)
               * (nd.astype(jnp.float32) + cfg.K * cfg.alpha)
               < nd.astype(jnp.float32))
    z_doc = jnp.where(use_tok, z_tok, z_unif)
    return (jax.random.uniform(kw, shape), jax.random.uniform(kwa, shape),
            z_doc, jax.random.uniform(kda, shape))


def _ndk_from_z(z: jax.Array, valid: jax.Array, k: int) -> jax.Array:
    """[B, L] assignments -> [B, K] doc-topic counts (row-wise one-hot sum)."""
    oh = jax.nn.one_hot(z, k, dtype=jnp.int32)
    return jnp.sum(oh * valid[..., None].astype(jnp.int32), axis=1)


@partial(jax.jit, static_argnames=("cfg", "fcfg"))
def fold_in_batch(model: lda.FrozenModel, w: jax.Array, valid: jax.Array,
                  doc_keys: jax.Array, cfg: lda.LDAConfig,
                  fcfg: FoldInConfig) -> jax.Array:
    """Fold a batch of unseen documents into a frozen model; return θ [B, K].

    ``w``/``valid`` are the [B, L] packed layout of ``pack_docs``;
    ``doc_keys`` is a [B] batch of PRNG keys (one per document -- the
    batch-composition-independence contract, see module docstring).

    One sweep resamples every token once against the sweep-start state
    (the serving analogue of the training block: the MH correction makes
    the stale proposals valid, same argument as the paper's asynchrony).
    """
    b, l = w.shape
    w_flat = w.reshape(b * l)
    nd = jnp.sum(valid.astype(jnp.int32), axis=1)                  # [B]

    init_keys = jax.vmap(lambda k: jax.random.fold_in(k, 0x1d4))(doc_keys)
    z = jax.vmap(lambda k: jax.random.randint(k, (l,), 0, cfg.K,
                                              dtype=jnp.int32))(init_keys)

    def sweep(s, carry):
        z, ndk_acc = carry
        sweep_keys = jax.vmap(lambda k: jax.random.fold_in(k, s))(doc_keys)
        u_w, u_wa, z_d, u_da = jax.vmap(
            lambda k, zr, n: _doc_randoms(k, zr, n, cfg))(sweep_keys, z, nd)
        # [B, S, L] -> [S, B*L] flat token order
        rng = lda.MHRandoms(*(r.transpose(1, 0, 2).reshape(cfg.mh_steps, b * l)
                              for r in (u_w, u_wa, z_d, u_da)))
        ndk = _ndk_from_z(z, valid, cfg.K)
        ndk_rows = jnp.broadcast_to(
            ndk[:, None, :], (b, l, cfg.K)).reshape(b * l, cfg.K)
        z_new = lda.sample_tokens_frozen(
            model, rng, z.reshape(b * l), w_flat, ndk_rows, cfg,
            use_kernels=fcfg.use_kernels, interpret=fcfg.kernel_interpret)
        z_new = jnp.where(valid, z_new.reshape(b, l), z)
        ndk_acc = ndk_acc + jnp.where(
            s >= fcfg.burnin, _ndk_from_z(z_new, valid, cfg.K), 0)
        return z_new, ndk_acc

    _, ndk_acc = jax.lax.fori_loop(
        0, fcfg.num_sweeps, sweep, (z, jnp.zeros((b, cfg.K), jnp.int32)))
    samples = fcfg.num_sweeps - fcfg.burnin
    ndk_avg = ndk_acc.astype(jnp.float32) / samples
    return ((ndk_avg + cfg.alpha)
            / (nd.astype(jnp.float32)[:, None] + cfg.K * cfg.alpha))


def fold_in_docs(model: lda.FrozenModel, docs: Sequence[np.ndarray],
                 cfg: lda.LDAConfig, fcfg: FoldInConfig,
                 seeds: Optional[Sequence[int]] = None,
                 length: Optional[int] = None) -> np.ndarray:
    """Convenience one-shot fold-in for a list of docs (no batching policy;
    the query engine adds padding-bucket batching on top)."""
    if length is None:
        length = max((len(d) for d in docs), default=1) or 1
    w, valid = pack_docs(docs, length)
    if seeds is None:
        seeds = range(len(docs))
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    theta = fold_in_batch(model, jnp.asarray(w), jnp.asarray(valid), keys,
                          cfg, fcfg)
    return np.asarray(theta)
