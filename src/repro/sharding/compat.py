"""Version-compat shims for JAX SPMD APIs.

``jax.shard_map`` was promoted out of ``jax.experimental`` only recently;
older jax (e.g. 0.4.x) spells it ``jax.experimental.shard_map.shard_map``
with ``check_rep`` instead of ``check_vma``.  Every shard_map call in this
repo goes through this wrapper so both spellings work.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
