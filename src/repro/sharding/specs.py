"""PartitionSpec rules: parameters, optimizer state, activations, caches.

Rules are keyed on parameter *path suffixes* so every architecture flows
through one table.  The mesh has axes (pod?, data, model); ``MeshCtx``
carries the axis names so the same model code runs single-device (tests),
single-pod (16x16) and multi-pod (2x16x16).

Layout summary (DESIGN.md section 5):
  embed/lm_head tables   : P(model, None)   -- vocab rows, cyclic physical order
  attn wq/wk/wv          : P(None, model)   -- shard heads
  attn wo                : P(model, None)
  mlp w_gate/w_up        : P(None, model)   -- shard d_ff
  mlp w_down             : P(model, None)
  MoE experts            : P(model, ...)    -- expert-parallel (cyclic owners)
  MLA w_dkv (latent)     : replicated       (latent dim is small and shared)
  MLA w_uk/w_uv          : P(None, model)
  ssm in_proj/out_proj   : P(None, model) / P(model, None)  -- shard d_inner
  norms / scalars / router: replicated
Activations:
  train/prefill hidden   : P(dp, None, None)      (batch over pod+data)
  KV caches              : P(dp, None, None, None) batch-sharded, except
  long-context (batch 1) : P(None, "data", ...)    sequence-sharded cache
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """Mesh + axis roles.  ``mesh is None`` means single-device reference
    semantics everywhere (smoke tests)."""

    mesh: Optional[Mesh]
    dp: Tuple[str, ...]          # data-parallel axes, e.g. ("pod", "data")
    model: Optional[str]         # tensor/expert-parallel axis

    @property
    def num_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.devices.size

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        out = 1
        for ax in self.dp:
            out *= sizes[ax]
        return out

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model is None:
            return 1
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[self.model]

    def named(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(spec))


SINGLE = MeshCtx(None, (), None)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _param_rules(model_axis: str):
    """(regex on '/'-joined path, spec builder taking leaf ndim)."""
    m = model_axis

    def two(a, b):
        # spec for the *trailing two* dims; leading (stacked layer) dims None
        return lambda nd: P(*([None] * (nd - 2) + [a, b]))

    def three(a, b, c):
        return lambda nd: P(*([None] * (nd - 3) + [a, b, c]))

    def repl(nd):
        return P()

    return [
        (r"embed/table$", two(m, None)),
        (r"lm_head/table$", two(m, None)),
        # Experts: expert dim over the model axis (cyclic owners), and the
        # d_model dim ZeRO-sharded over the dp axes for storage -- gathered
        # per use inside the MoE shard_map (models/moe.py).  Without this the
        # expert tensors (the bulk of an MoE's parameters) are replicated
        # data-parallel-wise and blow the HBM budget.
        (r"experts/w_gate$", three(m, "__dp__", None)),
        (r"experts/w_up$", three(m, "__dp__", None)),
        (r"experts/w_down$", three(m, "__dp__", None)),
        (r"router$", repl),
        (r"attn.*/wq$", two(None, m)),
        (r"attn.*/wk$", two(None, m)),
        (r"attn.*/wv$", two(None, m)),
        (r"attn.*/wo$", two(m, None)),
        (r"attn.*/w_dkv$", repl),          # MLA latent down-proj: small, replicated
        (r"attn.*/w_uk$", two(None, m)),
        (r"attn.*/w_uv$", two(None, m)),
        (r"(mlp|shared)/w_gate$", two(None, m)),
        (r"(mlp|shared)/w_up$", two(None, m)),
        (r"(mlp|shared)/w_down$", two(m, None)),
        (r"ssm/in_proj$", two(None, m)),
        (r"ssm/out_proj$", two(m, None)),
        (r"ssm/conv_w$", two(None, m)),
        (r"ssm/conv_b$", lambda nd: P(*([None] * (nd - 1) + [m]))),
        (r".*", repl),                     # norms, scalars, biases, gates
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, ctx: MeshCtx):
    """PartitionSpec tree matching ``params``."""
    if ctx.mesh is None or ctx.model is None:
        return jax.tree.map(lambda _: P(), params)
    rules = _param_rules(ctx.model)

    def one(path, leaf):
        s = _path_str(path)
        for pat, builder in rules:
            if re.search(pat, s):
                spec = builder(leaf.ndim)
                # resolve the "__dp__" placeholder to this mesh's dp axes
                parts = tuple(tuple(ctx.dp) if p == "__dp__" else p
                              for p in spec)
                return P(*parts)
        return P()

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_specs(params, ctx: MeshCtx):
    """ZeRO-style specs for optimizer moments / gradient accumulators.

    Parameters are model-sharded but dp-replicated (they are read by every
    forward).  Their f32 moments and microbatch grad accumulators are only
    read/written by the optimizer, so they additionally shard over the dp
    axes: pick the first dp-divisible dim the param spec leaves None.
    Cuts optimizer-state HBM by dp_size (16-32x) -- measured 8.5 -> 0.5
    GiB/chip on phi3 train_4k.  The all-gather of the parameter delta per
    step is params/dp bytes, inserted automatically by GSPMD.
    """
    base = param_specs(params, ctx)
    if ctx.mesh is None:
        return base
    dp = tuple(ctx.dp)
    dpsz = ctx.dp_size

    def widen(spec, leaf):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        used = set()
        for p in parts:
            for ax in (p if isinstance(p, tuple) else (p,)):
                used.add(ax)
        if any(a in used for a in dp):
            return P(*parts)
        for i in list(range(1, leaf.ndim)) + [0]:
            if parts[i] is None and leaf.shape[i] and leaf.shape[i] % dpsz == 0:
                parts[i] = dp
                return P(*parts)
        return P(*parts)

    return jax.tree.map(widen, base, params,
                        is_leaf=lambda s: isinstance(s, P))


def param_shardings(params, ctx: MeshCtx):
    if ctx.mesh is None:
        return None
    return jax.tree.map(lambda s: ctx.named(s), param_specs(params, ctx),
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Activation / cache / batch rules
# ---------------------------------------------------------------------------

def batch_spec(ctx: MeshCtx) -> P:
    return P(tuple(ctx.dp)) if ctx.dp else P()


# Residual-stream sharding between blocks.  "dp" = batch only (classic);
# "dp_model" additionally shards d_model over the model axis, which shrinks
# the per-layer scan carry (the activation-checkpoint working set) by the
# model-axis size at the cost of re-gather collectives per block -- the
# trade is measured in EXPERIMENTS.md section Perf.
ACTIVATION_SHARDING = "dp_model"


def hidden_spec(ctx: MeshCtx, cfg=None) -> P:
    if not ctx.dp:
        return P()
    mode = (cfg.activation_sharding if cfg is not None
            and getattr(cfg, "activation_sharding", "") else
            ACTIVATION_SHARDING)
    if mode == "dp_seq" and ctx.model is not None:
        # sequence over the model axis: pairs with seq-parallel attention
        # (no boundary reshard around the attention block)
        return P(tuple(ctx.dp), ctx.model, None)
    if mode == "dp_model" and ctx.model is not None:
        return P(tuple(ctx.dp), None, ctx.model)
    return P(tuple(ctx.dp), None, None)


def tokens_spec(ctx: MeshCtx) -> P:
    return P(tuple(ctx.dp), None) if ctx.dp else P()


def cache_specs(cfg: ModelConfig, shape: InputShape, ctx: MeshCtx):
    """Spec builders for decode caches.

    Returns a dict of spec-functions keyed by cache kind; transformer.py
    applies them leaf-wise.  For long_500k (batch 1) attention caches are
    **sequence-sharded** over the data axis (the distributed-LSE decode
    path); otherwise batch-sharded.
    """
    if ctx.mesh is None:
        none = P()
        return {"kv": none, "mla": none, "ssm_state": none, "conv": none,
                "seq_axis_sharded": False}
    dp = tuple(ctx.dp)
    m = ctx.model
    seq_shard = shape.global_batch < ctx.dp_size
    if seq_shard:
        # long-context (batch 1): [L, B, S, KV, hd] -- sequence over the
        # data axes (the distributed-LSE decode path), head_dim over model
        # (KV head counts are small and non-divisible; head_dim always is).
        kv = P(None, None, dp, None, m)
        mla = P(None, None, dp, m)            # latent dim over model
        ssm_state = P(None, None, None, m, None)  # [L,B,H,P,N]: P over model
        conv = P(None, None, None, m)         # channels over model
    else:
        kv = P(None, dp, None, None, m)
        mla = P(None, dp, None, m)
        ssm_state = P(None, dp, None, m, None)
        conv = P(None, dp, None, m)
    return {"kv": kv, "mla": mla, "ssm_state": ssm_state, "conv": conv,
            "seq_axis_sharded": seq_shard}
