"""Synthetic LM data pipeline for the assigned architectures.

Token frequencies are drawn Zipfian (like the paper's corpus, Fig. 4) so the
cyclic vocab-sharded embedding's load-balance property is exercised by
training, not just asserted.  For the "loss actually decreases" end-to-end
driver we generate sequences with *learnable structure*: a random order-1
Markov chain over the vocabulary (peaked transitions), which a few hundred
steps of a ~100M model can visibly compress.

Host-side numpy generators yielding device-ready dict batches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    zipf_exponent: float = 1.1
    branching: int = 4          # Markov out-degree (lower = more learnable)
    seed: int = 0
    cond_len: int = 0           # conditioning stub (vlm/audio); 0 = none
    cond_dim: int = 0


class MarkovZipfSource:
    """Order-1 Markov chain whose stationary distribution is ~Zipfian."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        base = 1.0 / np.arange(1, v + 1) ** cfg.zipf_exponent
        base /= base.sum()
        # each token transitions to `branching` successors, biased to the head
        self.succ = np.stack([
            rng.choice(v, size=cfg.branching, p=base) for _ in range(v)
        ])  # [V, branching]
        self.succ_p = rng.dirichlet(np.full(cfg.branching, 0.5), size=v)
        self.base = base
        self.rng = rng

    def batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.batch_size, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = self.rng.choice(cfg.vocab_size, size=b, p=self.base)
        # vectorised chain: pick a successor branch per (b, t)
        branch = (self.rng.random((b, s))[..., None]
                  < np.cumsum(self.succ_p, -1)[toks[:, 0]][:, None, :]
                  ).argmax(-1)  # placeholder; refined per step below
        for t in range(s):
            cur = toks[:, t]
            cdf = np.cumsum(self.succ_p[cur], axis=-1)
            k = (self.rng.random((b, 1)) < cdf).argmax(-1)
            toks[:, t + 1] = self.succ[cur, k]
        out = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }
        if cfg.cond_len:
            out["cond"] = self.rng.standard_normal(
                (b, cfg.cond_len, cfg.cond_dim)).astype(np.float32)
        return out

    def batches(self, n: int) -> Iterator[Dict[str, np.ndarray]]:
        for _ in range(n):
            yield self.batch()


def token_frequencies(source: MarkovZipfSource, num_batches: int = 8
                      ) -> np.ndarray:
    """Empirical token frequencies (rank-ordered check for tests)."""
    counts = np.zeros(source.cfg.vocab_size, np.int64)
    for b in source.batches(num_batches):
        counts += np.bincount(b["tokens"].reshape(-1),
                              minlength=source.cfg.vocab_size)
    return counts
