"""Shard-visit lease bookkeeping for the elastic worker pool.

The stream schedule (``StreamingLoader.schedule``) is a list of visits
``(epoch, pos, shard_id)``; the network parameter server hands them to
workers as exclusive, re-assignable *leases* (DESIGN.md section 15).
This module is the pure state machine -- numpy/stdlib only, no sockets --
so the policy is unit-testable and the straggler benchmark can drive it
in simulation.

Invariants:

  * **Shard exclusivity**: a shard with an active lease is locked, and a
    shard's visits are granted in schedule (epoch) order -- so the z file
    a worker reads is always the state its epoch expects, and two workers
    can never hold the same shard (which would double-apply deltas).
  * **Exactly-once completion**: a visit moves pending -> active ->
    done; ``release``/``release_worker`` (worker death, straggler
    re-queue) moves it back to pending, so every visit is *completed*
    exactly once even if it was *attempted* several times.

Assignment modes:

  * ``dynamic``       one global queue; free workers pull the next
                      available visit (stragglers naturally take fewer);
  * ``static``        visits pre-partitioned round-robin over worker
                      slots; a worker only sees its own slot (the
                      no-re-assignment baseline);
  * ``static_steal``  static, but an idle worker steals the next
                      unstarted visit from the most-loaded slot -- the
                      slowest worker's unstarted shards are re-queued
                      onto whoever is free.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

PENDING, ACTIVE, DONE = 0, 1, 2
MODES = ("dynamic", "static", "static_steal")


class Lease(NamedTuple):
    """One granted shard visit."""
    lease_id: int
    epoch: int
    pos: int
    shard_id: int


class ShardLeaseBook:
    """Exclusive, re-assignable leases over a stream visit schedule."""

    def __init__(self, schedule: List[Tuple[int, int, int]], *,
                 mode: str = "dynamic", slots: int = 0):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES} (got {mode!r})")
        if mode != "dynamic" and slots < 1:
            raise ValueError(f"{mode} assignment needs slots >= 1")
        self.mode = mode
        self.slots = int(slots)
        # one record per visit, in schedule order; lease_id == index
        self._visits = [{
            "epoch": int(e), "pos": int(p), "shard": int(s),
            "state": PENDING, "worker": None,
            "slot": (i % slots if mode != "dynamic" else None),
        } for i, (e, p, s) in enumerate(schedule)]
        self.stolen = 0
        self.reassigned = 0             # release_worker re-queues

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._visits)

    @property
    def done(self) -> int:
        return sum(v["state"] == DONE for v in self._visits)

    @property
    def active(self) -> int:
        return sum(v["state"] == ACTIVE for v in self._visits)

    def all_done(self) -> bool:
        return all(v["state"] == DONE for v in self._visits)

    def visit(self, lease_id: int) -> dict:
        """The visit record behind a lease id (read-only by convention)."""
        return self._visits[lease_id]

    def slot_backlog(self) -> Dict[int, int]:
        """Pending visit count per static slot (None key for orphans)."""
        out: Dict[int, int] = {}
        for v in self._visits:
            if v["state"] == PENDING:
                out[v["slot"]] = out.get(v["slot"], 0) + 1
        return out

    # -- the state machine ---------------------------------------------------
    def _heads(self):
        """Grantable visits: for each shard, its earliest not-done visit,
        provided that visit is pending (an active one locks the shard)."""
        seen = set()
        for i, v in enumerate(self._visits):
            if v["state"] == DONE or v["shard"] in seen:
                continue
            seen.add(v["shard"])
            if v["state"] == PENDING:
                yield i, v

    def acquire(self, worker: int, slot: Optional[int] = None
                ) -> Tuple[str, Optional[Lease]]:
        """Try to grant the next visit to ``worker`` (static modes route
        by ``slot``).  Returns ``("lease", Lease)``, ``("wait", None)``
        (retry later) or ``("done", None)`` (schedule drained)."""
        if self.all_done():
            return "done", None
        heads = list(self._heads())
        pick = None
        if self.mode == "dynamic":
            pick = heads[0] if heads else None
        else:
            mine = [h for h in heads if h[1]["slot"] in (slot, None)]
            if mine:
                pick = mine[0]
            elif self.mode == "static_steal" and heads:
                # steal from the most backlogged slot (the straggler)
                backlog = self.slot_backlog()
                victim = max(backlog, key=lambda s: backlog[s])
                stealable = [h for h in heads if h[1]["slot"] == victim]
                if stealable:
                    pick = stealable[-1]    # its *last* unstarted visit
                    pick[1]["slot"] = slot
                    self.stolen += 1
        if pick is None:
            return "wait", None
        i, v = pick
        v["state"], v["worker"] = ACTIVE, worker
        return "lease", Lease(i, v["epoch"], v["pos"], v["shard"])

    def complete(self, lease_id: int) -> bool:
        """Mark a granted visit done.  False if it was not active (e.g.
        already re-queued after an eviction and completed by another
        worker -- the caller should treat its work as superseded)."""
        v = self._visits[lease_id]
        if v["state"] != ACTIVE:
            return False
        v["state"], v["worker"] = DONE, None
        return True

    def release(self, lease_id: int) -> None:
        """Re-queue one granted visit (worker gave it up)."""
        v = self._visits[lease_id]
        if v["state"] == ACTIVE:
            v["state"], v["worker"] = PENDING, None
            self.reassigned += 1

    def release_worker(self, worker: int) -> int:
        """Re-queue everything a (dead) worker held; its statically
        assigned pending visits become orphans any worker may take.
        Returns the number of active leases re-queued."""
        n = 0
        for v in self._visits:
            if v["state"] == ACTIVE and v["worker"] == worker:
                v["state"], v["worker"] = PENDING, None
                n += 1
        self.reassigned += n
        return n

    def orphan_slot(self, slot: int) -> int:
        """Static modes: mark a dead worker's unstarted visits takeable
        by anyone (slot None), so pure ``static`` cannot deadlock."""
        n = 0
        for v in self._visits:
            if v["state"] == PENDING and v["slot"] == slot:
                v["slot"] = None
                n += 1
        return n

    def stats(self) -> dict:
        return {"total": len(self._visits), "done": self.done,
                "active": self.active, "stolen": self.stolen,
                "reassigned": self.reassigned, "mode": self.mode}
