"""Out-of-core streaming corpus pipeline (the paper's "Web-scale" axis).

The paper's headline claim is processing 135x more data than Spark LDA by
keeping *partitioned data* flowing past the parameter servers: the corpus
never lives in one memory, only the model does.  This module is the host
side of that claim -- a sharded on-disk token store plus a prefetching
loader -- so corpora far larger than host RAM stream through the PS client
shard by shard.

Layout (one directory):

  stream.json            manifest: vocab_size, shard geometry, per-shard
                         valid token/doc counts
  word_freq.npy          [V] corpus word frequencies (ids are expected to
                         be frequency-ordered already -- data/corpus.py's
                         ``reindex`` contract; an out-of-core builder does
                         that ordering as its own offline pass)
  shard_00000.w.npy      [tokens_per_shard] int32 word ids  (padded)
  shard_00000.d.npy      [tokens_per_shard] int32 *shard-local* doc ids
  shard_00000.doc_start.npy / .doc_len.npy   [doc_cap] int32 (padded)
  shard_00000.z.npy      [tokens_per_shard] int32 topic assignments --
                         created by the trainer, rewritten after every
                         visit (the paper's section-3.5 stance: ``z`` is
                         part of the *data*, counts are derived)

Every shard has identical array shapes (``tokens_per_shard`` tokens,
``doc_cap`` doc slots), so one jitted executor step serves the whole
stream with no per-shard recompilation.  Padding tokens have
``w == d == 0`` and are invalid (``index >= n_tokens``).

This module is deliberately **numpy-only** (no jax import): it is a data
pipeline that runs on CPU feeder hosts, and the streaming benchmark's
measured process must not carry an accelerator runtime in its RSS.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time as _time
from concurrent.futures import ThreadPoolExecutor
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

# stdlib-only telemetry (repro.obs never imports jax/numpy at module
# scope), so the numpy-only constraint above holds
from repro import obs as _obs

MANIFEST = "stream.json"
WORD_FREQ = "word_freq.npy"
_VERSION = 1


# ---------------------------------------------------------------------------
# Manifest / shard records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamMeta:
    """Manifest of a stream directory (everything uniform across shards)."""

    vocab_size: int
    tokens_per_shard: int   # padded token capacity of every shard
    doc_cap: int            # padded doc capacity of every shard
    num_shards: int
    num_tokens: int         # total *valid* tokens
    num_docs: int
    shard_tokens: Tuple[int, ...]   # valid tokens per shard
    shard_docs: Tuple[int, ...]     # valid docs per shard

    def to_json(self) -> dict:
        return {"version": _VERSION,
                "vocab_size": self.vocab_size,
                "tokens_per_shard": self.tokens_per_shard,
                "doc_cap": self.doc_cap,
                "num_shards": self.num_shards,
                "num_tokens": self.num_tokens,
                "num_docs": self.num_docs,
                "shard_tokens": list(self.shard_tokens),
                "shard_docs": list(self.shard_docs)}

    @classmethod
    def from_json(cls, obj: dict) -> "StreamMeta":
        if obj.get("version") != _VERSION:
            raise ValueError(f"unsupported stream manifest version "
                             f"{obj.get('version')!r}")
        return cls(vocab_size=obj["vocab_size"],
                   tokens_per_shard=obj["tokens_per_shard"],
                   doc_cap=obj["doc_cap"],
                   num_shards=obj["num_shards"],
                   num_tokens=obj["num_tokens"],
                   num_docs=obj["num_docs"],
                   shard_tokens=tuple(obj["shard_tokens"]),
                   shard_docs=tuple(obj["shard_docs"]))


@dataclasses.dataclass(frozen=True)
class StreamShard:
    """One shard's arrays (all padded to the uniform shapes).

    ``z`` is None until the trainer has initialised assignments for this
    shard.  ``valid()`` materialises the padding mask lazily (it is pure
    geometry: the first ``n_tokens`` entries are real)."""

    shard_id: int
    w: np.ndarray          # [tokens_per_shard] int32
    d: np.ndarray          # [tokens_per_shard] int32, shard-local doc ids
    doc_start: np.ndarray  # [doc_cap] int32
    doc_len: np.ndarray    # [doc_cap] int32
    n_tokens: int          # valid token count
    n_docs: int            # valid doc count
    z: Optional[np.ndarray] = None

    def valid(self) -> np.ndarray:
        return np.arange(self.w.shape[0]) < self.n_tokens

    @property
    def nbytes(self) -> int:
        n = self.w.nbytes + self.d.nbytes + self.doc_start.nbytes + \
            self.doc_len.nbytes
        if self.z is not None:
            n += self.z.nbytes
        return n


def _shard_file(path: str, sid: int, name: str) -> str:
    return os.path.join(path, f"shard_{sid:05d}.{name}.npy")


def _atomic_save(fn: str, arr: np.ndarray) -> None:
    tmp = fn + ".tmp.npy"
    np.save(tmp, arr)
    os.replace(tmp, fn)


# ---------------------------------------------------------------------------
# Writer
# ---------------------------------------------------------------------------

class ShardedCorpusWriter:
    """Shard a document stream into the on-disk layout above.

    Documents are appended in arrival order; a shard is flushed (padded to
    the uniform geometry) whenever the next document would overflow its
    token capacity or doc cap.  Memory is bounded by one shard's buffers
    regardless of corpus size -- this is what lets the benchmark *write* a
    corpus bigger than its RSS budget, not just read one.
    """

    def __init__(self, path: str, vocab_size: int, tokens_per_shard: int,
                 doc_cap: Optional[int] = None):
        if tokens_per_shard <= 0:
            raise ValueError("tokens_per_shard must be positive")
        self.path = path
        self.vocab_size = int(vocab_size)
        self.tokens_per_shard = int(tokens_per_shard)
        self.doc_cap = int(doc_cap) if doc_cap else max(
            64, tokens_per_shard // 8)
        os.makedirs(path, exist_ok=True)
        self._ws: List[np.ndarray] = []      # per-doc token arrays
        self._lens: List[int] = []
        self._ntok = 0
        self._word_freq = np.zeros(self.vocab_size, np.int64)
        self._shard_tokens: List[int] = []
        self._shard_docs: List[int] = []
        self._closed = False

    # -- appending ---------------------------------------------------------
    def add_document(self, w: Sequence[int]) -> None:
        w = np.asarray(w, np.int32)
        n = int(w.shape[0])
        if n == 0:
            return
        if n > self.tokens_per_shard:
            raise ValueError(f"document of {n} tokens exceeds "
                             f"tokens_per_shard={self.tokens_per_shard}")
        if (self._ntok + n > self.tokens_per_shard
                or len(self._lens) >= self.doc_cap):
            self._flush()
        self._ws.append(w)
        self._lens.append(n)
        self._ntok += n

    def add_tokens(self, w: np.ndarray, doc_lens: np.ndarray) -> None:
        """Bulk append: flat token array + per-document lengths.

        Vectorised doc->shard assignment (one ``searchsorted`` per flush,
        not one Python call per document) -- the path the synthetic
        benchmark generator uses at tens of millions of tokens.
        """
        w = np.asarray(w, np.int32)
        doc_lens = np.asarray(doc_lens, np.int64)
        assert int(doc_lens.sum()) == w.shape[0], "doc_lens must tile w"
        if doc_lens.size and int(doc_lens.max()) > self.tokens_per_shard:
            raise ValueError("a document exceeds tokens_per_shard")
        starts = np.concatenate([[0], np.cumsum(doc_lens)[:-1]])
        i = 0
        while i < doc_lens.shape[0]:
            cum = np.cumsum(doc_lens[i:]) + self._ntok
            fit = int(np.searchsorted(cum, self.tokens_per_shard, "right"))
            fit = min(fit, self.doc_cap - len(self._lens))
            if fit == 0:
                self._flush()
                continue
            lo = int(starts[i])
            hi = int(starts[i + fit - 1] + doc_lens[i + fit - 1])
            self._ws.append(w[lo:hi])
            self._lens.extend(int(x) for x in doc_lens[i:i + fit])
            self._ntok += hi - lo
            i += fit

    def add_corpus(self, corpus) -> None:
        """Append every document of an in-memory ``data.corpus.Corpus``
        (which is already frequency-ordered -- the ``reindex`` contract)."""
        self.add_tokens(corpus.w, corpus.doc_len.astype(np.int64))

    # -- flushing ----------------------------------------------------------
    def _flush(self) -> None:
        if not self._lens:
            return
        sid = len(self._shard_tokens)
        cap, dcap = self.tokens_per_shard, self.doc_cap
        w = np.concatenate(self._ws).astype(np.int32)
        n = int(w.shape[0])
        ndocs = len(self._lens)
        doc_len = np.zeros(dcap, np.int32)
        doc_len[:ndocs] = self._lens
        doc_start = np.zeros(dcap, np.int32)
        doc_start[1:ndocs] = np.cumsum(doc_len[:ndocs - 1])
        d = np.zeros(cap, np.int32)
        d[:n] = np.repeat(np.arange(ndocs, dtype=np.int32),
                          doc_len[:ndocs])
        wpad = np.zeros(cap, np.int32)
        wpad[:n] = w
        if (w >= self.vocab_size).any() or (w < 0).any():
            raise ValueError("word id out of range for vocab_size")
        self._word_freq += np.bincount(w, minlength=self.vocab_size)
        _atomic_save(_shard_file(self.path, sid, "w"), wpad)
        _atomic_save(_shard_file(self.path, sid, "d"), d)
        _atomic_save(_shard_file(self.path, sid, "doc_start"), doc_start)
        _atomic_save(_shard_file(self.path, sid, "doc_len"), doc_len)
        self._shard_tokens.append(n)
        self._shard_docs.append(ndocs)
        self._ws, self._lens, self._ntok = [], [], 0

    def close(self) -> StreamMeta:
        if self._closed:
            raise RuntimeError("writer already closed")
        self._flush()
        self._closed = True
        meta = StreamMeta(
            vocab_size=self.vocab_size,
            tokens_per_shard=self.tokens_per_shard,
            doc_cap=self.doc_cap,
            num_shards=len(self._shard_tokens),
            num_tokens=int(sum(self._shard_tokens)),
            num_docs=int(sum(self._shard_docs)),
            shard_tokens=tuple(self._shard_tokens),
            shard_docs=tuple(self._shard_docs))
        np.save(os.path.join(self.path, WORD_FREQ), self._word_freq)
        tmp = os.path.join(self.path, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta.to_json(), f, indent=1)
        os.replace(tmp, os.path.join(self.path, MANIFEST))
        return meta

    def __enter__(self) -> "ShardedCorpusWriter":
        return self

    def __exit__(self, et, ev, tb) -> None:
        if et is None and not self._closed:
            self.close()


def write_sharded(path: str, corpus, tokens_per_shard: int,
                  doc_cap: Optional[int] = None) -> StreamMeta:
    """Shard an in-memory corpus into ``path`` (tests/launcher shortcut)."""
    w = ShardedCorpusWriter(path, corpus.vocab_size, tokens_per_shard,
                            doc_cap=doc_cap)
    w.add_corpus(corpus)
    return w.close()


# ---------------------------------------------------------------------------
# Reader
# ---------------------------------------------------------------------------

class ShardedCorpusReader:
    """Open a stream directory; shard reads are memory-mapped by default."""

    def __init__(self, path: str):
        self.path = path
        manifest = os.path.join(path, MANIFEST)
        if not os.path.exists(manifest):
            raise FileNotFoundError(f"no stream manifest at {manifest}")
        with open(manifest) as f:
            self.meta = StreamMeta.from_json(json.load(f))

    @property
    def num_shards(self) -> int:
        return self.meta.num_shards

    def __len__(self) -> int:
        return self.meta.num_shards

    @property
    def word_freq(self) -> np.ndarray:
        return np.load(os.path.join(self.path, WORD_FREQ))

    def shard_nbytes(self, with_z: bool = True) -> int:
        """Bytes one loaded shard occupies (the loader's budgeting unit)."""
        per_tok = 4 * (3 if with_z else 2)          # w, d[, z] int32
        return (self.meta.tokens_per_shard * per_tok
                + self.meta.doc_cap * 8)            # doc_start + doc_len

    def shard(self, sid: int, mmap: bool = True,
              load_z: bool = True) -> StreamShard:
        mode = "r" if mmap else None
        z = None
        if load_z and self.has_z(sid):
            z = np.load(self.z_path(sid), mmap_mode=mode)
        return StreamShard(
            shard_id=sid,
            w=np.load(_shard_file(self.path, sid, "w"), mmap_mode=mode),
            d=np.load(_shard_file(self.path, sid, "d"), mmap_mode=mode),
            doc_start=np.load(_shard_file(self.path, sid, "doc_start"),
                              mmap_mode=mode),
            doc_len=np.load(_shard_file(self.path, sid, "doc_len"),
                            mmap_mode=mode),
            n_tokens=self.meta.shard_tokens[sid],
            n_docs=self.meta.shard_docs[sid],
            z=z)

    # -- topic-assignment persistence (paper section 3.5: z is data) ------
    def z_path(self, sid: int) -> str:
        return _shard_file(self.path, sid, "z")

    def has_z(self, sid: int) -> bool:
        return os.path.exists(self.z_path(sid))

    def read_z(self, sid: int) -> np.ndarray:
        return np.load(self.z_path(sid))

    def write_z(self, sid: int, z: np.ndarray) -> None:
        z = np.asarray(z, np.int32)
        assert z.shape == (self.meta.tokens_per_shard,), z.shape
        _atomic_save(self.z_path(sid), z)


def rebuild_counts_from_stream(reader: ShardedCorpusReader, num_topics: int
                               ) -> Tuple[np.ndarray, np.ndarray]:
    """Stream every shard's persisted ``z`` and histogram the counts.

    This is the paper's section-3.5 recovery (counts are derived from the
    checkpointed assignments) *and* the epoch-level conservation oracle
    the tests assert against: after any number of epochs the PS state must
    equal exactly this histogram.  Memory: O(V x K) + one shard.
    """
    meta = reader.meta
    nwk = np.zeros((meta.vocab_size, num_topics), np.int64)
    nk = np.zeros(num_topics, np.int64)
    for sid in range(meta.num_shards):
        shard = reader.shard(sid)
        if shard.z is None:
            raise FileNotFoundError(f"shard {sid} has no z file -- "
                                    "initialise the stream trainer first")
        n = shard.n_tokens
        wv = np.asarray(shard.w[:n])
        zv = np.asarray(shard.z[:n])
        np.add.at(nwk, (wv, zv), 1)
        nk += np.bincount(zv, minlength=num_topics)
    return nwk, nk


# ---------------------------------------------------------------------------
# Loader: double-buffered prefetch + per-epoch shuffled shard order
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Cursor:
    """Loader position: ``pos`` indexes into epoch ``epoch``'s shard order.

    The cursor (plus the PS state and the on-disk ``z`` files) is the
    complete resumable training state -- it is what
    ``train.checkpoint.save_stream`` persists.
    """

    epoch: int = 0
    pos: int = 0

    def next(self, num_shards: int) -> "Cursor":
        if self.pos + 1 < num_shards:
            return Cursor(self.epoch, self.pos + 1)
        return Cursor(self.epoch + 1, 0)

    def to_json(self) -> dict:
        return {"epoch": self.epoch, "pos": self.pos}

    @classmethod
    def from_json(cls, obj: dict) -> "Cursor":
        return cls(epoch=int(obj["epoch"]), pos=int(obj["pos"]))


class StreamingLoader:
    """Double-buffered shard loader with per-epoch shard-order shuffling.

    The shard order of epoch ``e`` is the fixed-PRNG permutation
    ``default_rng([seed, e]).permutation(num_shards)`` -- deterministic
    given (seed, epoch), so a resumed run regenerates the identical
    schedule from the cursor alone.

    Prefetch is one shard deep (double buffer): while the consumer works
    on shard ``i``, a background thread materialises shard ``i+1`` from
    disk.  Peak loader memory is therefore ``2 * shard_nbytes``; pass
    ``memory_budget`` (bytes) to have that invariant checked up front.
    The prefetch is skipped when the next scheduled shard *is* the current
    one (possible at an epoch boundary) -- the consumer may still be
    rewriting its ``z`` file.
    """

    def __init__(self, reader: ShardedCorpusReader, seed: int = 0,
                 memory_budget: Optional[int] = None, prefetch: bool = True,
                 load_z: bool = True):
        self.reader = reader
        self.seed = int(seed)
        self.prefetch = prefetch
        self.load_z = load_z
        self.memory_budget = memory_budget
        if memory_budget is not None:
            need = 2 * reader.shard_nbytes(with_z=load_z)
            if need > memory_budget:
                raise ValueError(
                    f"double-buffered loader needs {need} bytes "
                    f"(2 shards) but memory_budget={memory_budget}; "
                    "use smaller shards or raise the budget")

    def order_for_epoch(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng([self.seed, int(epoch)])
        return rng.permutation(self.reader.num_shards)

    def schedule(self, start: Cursor = Cursor(), end_epoch: int = 1
                 ) -> List[Tuple[Cursor, int]]:
        """The full visit list ``[(cursor, shard_id), ...]`` from
        ``start`` to the end of epoch ``end_epoch - 1`` -- the exact
        sequence ``iterate`` walks (pure function of (seed, start))."""
        out = []
        cur = start
        while cur.epoch < end_epoch:
            order = self.order_for_epoch(cur.epoch)
            for pos in range(cur.pos, len(order)):
                out.append((Cursor(cur.epoch, pos), int(order[pos])))
            cur = Cursor(cur.epoch + 1, 0)
        return out

    _schedule = schedule

    def _load(self, sid: int) -> StreamShard:
        # materialised (mmap=False): the double buffer owns real RAM, and
        # the consumer gets plain arrays it can hand straight to a device.
        # The span lands on the loader thread's own trace track, so disk
        # reads visibly overlap the consumer's sweeps in the timeline.
        with _obs.span("stream.load", cat="stream", shard=sid):
            return self.reader.shard(sid, mmap=False, load_z=self.load_z)

    def iterate(self, start: Cursor = Cursor(), end_epoch: int = 1
                ) -> Iterator[Tuple[Cursor, int, StreamShard]]:
        """Yield ``(cursor, shard_id, shard)`` from ``start`` until the end
        of epoch ``end_epoch - 1``."""
        seq = self._schedule(start, end_epoch)
        if not seq:
            return
        if not self.prefetch:
            for cur, sid in seq:
                yield cur, sid, self._load(sid)
            return
        with ThreadPoolExecutor(max_workers=1) as ex:
            fut = ex.submit(self._load, seq[0][1])
            for j, (cur, sid) in enumerate(seq):
                reg = _obs.metrics_registry()
                tr = _obs.tracer()
                if fut is not None:
                    # hit: the prefetched shard was ready before the
                    # consumer asked; miss: the consumer stalls on disk
                    if reg is not None:
                        reg.counter("stream.prefetch_hit" if fut.done()
                                    else "stream.prefetch_miss").inc()
                    if reg is None and tr is None:
                        shard = fut.result()
                    else:
                        t0 = _time.perf_counter_ns()
                        shard = fut.result()
                        t1 = _time.perf_counter_ns()
                        if tr is not None:
                            tr.complete("stream.shard_wait", t0, t1,
                                        cat="stream", shard=sid)
                        if reg is not None:
                            reg.histogram("stream.shard_wait_ms").record(
                                (t1 - t0) / 1e6)
                else:
                    # prefetch was skipped (next shard == current: its z
                    # file was still being rewritten) -- a forced
                    # synchronous load, always a stall
                    if reg is not None:
                        reg.counter("stream.prefetch_skip").inc()
                    shard = self._load(sid)
                fut = None
                if j + 1 < len(seq) and seq[j + 1][1] != sid:
                    fut = ex.submit(self._load, seq[j + 1][1])
                yield cur, sid, shard
