"""Synthetic Zipfian corpus pipeline (ClueWeb12 stand-in).

The paper's corpus statistics that matter to the *system* are (a) the
Zipfian word-frequency distribution (paper Fig. 4) -- it drives the implicit
load-balancing argument -- and (b) scale.  This module generates LDA-
distributed corpora whose empirical word frequencies are Zipfian, and
produces the exact data layout the sampler consumes:

  * vocabulary ids are **frequency-ordered** (rank 0 = most common word),
    which is the paper's section 3.2 trick that makes cyclic partitioning
    load-balanced;
  * tokens are flattened (w, d) arrays grouped by document, with doc offset
    tables, padded to block/shard boundaries;
  * held-out docs are split half/half for fold-in perplexity evaluation.

Generation is host-side numpy (a data pipeline, not a model), as it would
be in production (CPU feeders, TPU consumers).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Corpus:
    """Flattened corpus, frequency-ordered vocabulary."""

    w: np.ndarray          # [N] word ids
    d: np.ndarray          # [N] doc ids
    doc_start: np.ndarray  # [D]
    doc_len: np.ndarray    # [D]
    vocab_size: int
    word_freq: np.ndarray  # [V] corpus frequency of each word id (desc.)

    @property
    def num_tokens(self) -> int:
        return int(self.w.shape[0])

    @property
    def num_docs(self) -> int:
        return int(self.doc_len.shape[0])

    def subset(self, frac: float, seed: int = 0) -> "Corpus":
        """Take the first ``frac`` of documents (the paper's 2.5%-10%
        subset experiments scale the corpus this way)."""
        ndocs = max(1, int(self.num_docs * frac))
        end = int(self.doc_start[ndocs - 1] + self.doc_len[ndocs - 1])
        return reindex(self.w[:end], self.d[:end], self.vocab_size)


def reindex(w: np.ndarray, d: np.ndarray, vocab_size: int) -> Corpus:
    """Rebuild offsets + frequency ordering for a token list."""
    # frequency-order the vocabulary (paper section 3.2)
    freq = np.bincount(w, minlength=vocab_size)
    order = np.argsort(-freq, kind="stable")
    rank_of = np.empty_like(order)
    rank_of[order] = np.arange(vocab_size)
    w = rank_of[w].astype(np.int32)
    freq = freq[order]

    # compact doc ids, grouped
    uniq, d_new = np.unique(d, return_inverse=True)
    sort = np.argsort(d_new, kind="stable")
    w, d_new = w[sort], d_new[sort].astype(np.int32)
    doc_len = np.bincount(d_new, minlength=len(uniq)).astype(np.int32)
    doc_start = _starts_of(doc_len)
    return Corpus(w, d_new, doc_start, doc_len, vocab_size, freq)


def generate_lda_corpus(seed: int, num_docs: int, mean_doc_len: int,
                        vocab_size: int, num_topics: int,
                        zipf_exponent: float = 1.05,
                        doc_topic_alpha: float = 0.08,
                        topic_concentration: float = 2000.0) -> Corpus:
    """Generate a corpus from the LDA generative process with a Zipfian base
    measure, so empirical frequencies follow Zipf's law (paper Fig. 4)."""
    rng = np.random.default_rng(seed)

    # Zipfian base measure over the vocabulary.
    base = 1.0 / np.arange(1, vocab_size + 1) ** zipf_exponent
    base /= base.sum()

    # Topic-word distributions: Dirichlet around the Zipf base (sparse-ish
    # topics that still mix to a Zipfian marginal).
    phi = rng.dirichlet(base * topic_concentration, size=num_topics)  # [K, V]

    doc_lens = np.maximum(rng.poisson(mean_doc_len, size=num_docs), 4)
    thetas = rng.dirichlet(np.full(num_topics, doc_topic_alpha), size=num_docs)

    ws: List[np.ndarray] = []
    ds: List[np.ndarray] = []
    for doc in range(num_docs):
        n = doc_lens[doc]
        zs = rng.choice(num_topics, size=n, p=thetas[doc])
        # vectorised per-topic word draws
        wdoc = np.empty(n, dtype=np.int64)
        for k in np.unique(zs):
            m = zs == k
            wdoc[m] = rng.choice(vocab_size, size=m.sum(), p=phi[k])
        ws.append(wdoc)
        ds.append(np.full(n, doc, dtype=np.int64))

    return reindex(np.concatenate(ws), np.concatenate(ds), vocab_size)


def synthetic_corpus(num_docs: int, vocab_size: int, *,
                     true_topics: Optional[int] = None,
                     model_topics: Optional[int] = None,
                     mean_doc_len: int = 60, seed: int = 0,
                     log_fn=None) -> Corpus:
    """The canonical synthetic-corpus recipe for examples/ and benchmarks/.

    Every demo and benchmark used to hand-roll its own
    ``generate_lda_corpus`` call with near-identical arguments; this is
    the single front door.  ``true_topics`` is the generative topic
    count; when omitted it defaults to half the *model's* topic count
    (``max(4, model_topics // 2)`` -- the convention the benchmarks
    converged on) or 16 if neither is given.  ``log_fn`` optionally
    prints the one-line corpus summary every caller used to format
    itself.
    """
    if true_topics is None:
        true_topics = max(4, model_topics // 2) if model_topics else 16
    corp = generate_lda_corpus(seed=seed, num_docs=num_docs,
                               mean_doc_len=mean_doc_len,
                               vocab_size=vocab_size,
                               num_topics=true_topics)
    if log_fn is not None:
        log_fn(f"corpus: {corp.num_tokens} tokens, {corp.num_docs} docs, "
               f"V={corp.vocab_size}")
    return corp


def corpus_from_docs(docs, vocab_size: Optional[int] = None) -> Corpus:
    """Build a ``Corpus`` from an iterable of token-id documents.

    The entry point behind ``LDAJob(docs=...)``.  NOTE: word ids are
    re-ranked by corpus frequency (``reindex`` -- the section-3.2
    contract every downstream component assumes); keep your own id->rank
    map if you need to translate back.  Empty documents are dropped.
    """
    ws: List[np.ndarray] = []
    ds: List[np.ndarray] = []
    for i, doc in enumerate(docs):
        a = np.asarray(doc, dtype=np.int64).ravel()
        if a.size == 0:
            continue
        ws.append(a)
        ds.append(np.full(a.size, i, np.int64))
    if not ws:
        raise ValueError("docs yielded no tokens; pass at least one "
                         "non-empty document")
    w = np.concatenate(ws)
    d = np.concatenate(ds)
    if w.min() < 0:
        raise ValueError("negative token ids in docs")
    if vocab_size is None:
        vocab_size = int(w.max()) + 1
    elif int(w.max()) >= vocab_size:
        raise ValueError(f"token id {int(w.max())} out of range for "
                         f"vocab_size={vocab_size}")
    return reindex(w, d, vocab_size)


def train_heldout_split(corpus: Corpus, heldout_frac: float = 0.1,
                        seed: int = 1) -> Tuple[Corpus, Corpus]:
    """Split documents into train/held-out sets."""
    rng = np.random.default_rng(seed)
    ndocs = corpus.num_docs
    held = rng.random(ndocs) < heldout_frac
    held_tok = held[corpus.d]
    train = reindex(corpus.w[~held_tok], corpus.d[~held_tok], corpus.vocab_size)
    heldout = reindex(corpus.w[held_tok], corpus.d[held_tok], corpus.vocab_size)
    # NOTE: reindex re-sorts each split's vocabulary by its own frequencies;
    # for evaluation the two must share word ids, so instead keep the parent
    # corpus ordering for the held-out split:
    heldout = Corpus(corpus.w[held_tok].astype(np.int32),
                     _compact_docs(corpus.d[held_tok]),
                     *_offsets(corpus.d[held_tok]),
                     corpus.vocab_size, corpus.word_freq)
    train = Corpus(corpus.w[~held_tok].astype(np.int32),
                   _compact_docs(corpus.d[~held_tok]),
                   *_offsets(corpus.d[~held_tok]),
                   corpus.vocab_size, corpus.word_freq)
    return train, heldout


def _compact_docs(d: np.ndarray) -> np.ndarray:
    _, inv = np.unique(d, return_inverse=True)
    return inv.astype(np.int32)


def _starts_of(doc_len: np.ndarray) -> np.ndarray:
    """Offsets from lengths; an empty doc set has *empty* offsets (not a
    phantom [0] entry -- the doc_start/doc_len lengths must always agree)."""
    if doc_len.shape[0] == 0:
        return np.zeros(0, np.int32)
    return np.concatenate([[0], np.cumsum(doc_len)[:-1]]).astype(np.int32)


def _offsets(d: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    dc = _compact_docs(d)
    doc_len = np.bincount(dc).astype(np.int32)
    return _starts_of(doc_len), doc_len


def fold_eval_split(corpus: Corpus, seed: int = 2
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Alternate tokens of each held-out doc into fold-in vs eval halves.
    Returns boolean masks (fold_mask, eval_mask) plus (w, d) unchanged."""
    rng = np.random.default_rng(seed)
    coin = rng.random(corpus.num_tokens) < 0.5
    return corpus.w, corpus.d, coin, ~coin


def shard_tokens(corpus: Corpus, num_shards: int, block_tokens: int
                 ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Partition documents across data-parallel workers (Spark partitions,
    paper Fig. 3).  Documents are assigned round-robin by size (greedy LPT)
    so token counts balance; each shard's arrays are padded to
    ``block_tokens``.  Returns per-shard (w, d_local, valid, doc_start,
    doc_len)."""
    order = np.argsort(-corpus.doc_len, kind="stable")
    loads = np.zeros(num_shards, dtype=np.int64)
    assign = np.empty(corpus.num_docs, dtype=np.int32)
    for doc in order:
        s = int(np.argmin(loads))
        assign[doc] = s
        loads[s] += corpus.doc_len[doc]

    shards = []
    for s in range(num_shards):
        docs = np.where(assign == s)[0]
        tok_mask = np.isin(corpus.d, docs)
        w = corpus.w[tok_mask]
        d = _compact_docs(corpus.d[tok_mask])
        doc_start, doc_len = _offsets(corpus.d[tok_mask])
        # every shard pads to at least one full block -- an empty shard
        # (num_shards > num_docs) still yields block-shaped, all-invalid
        # arrays, so downstream per-shard reshapes never see length 0
        pad = (-len(w)) % block_tokens
        if len(w) + pad == 0:
            pad = block_tokens
        valid = np.concatenate([np.ones(len(w), bool), np.zeros(pad, bool)])
        w = np.concatenate([w, np.zeros(pad, np.int32)])
        d = np.concatenate([d, np.zeros(pad, np.int32)])
        shards.append((w.astype(np.int32), d.astype(np.int32), valid,
                       doc_start, doc_len))
    return shards


def doc_term_matrix(corpus: Corpus, docs: np.ndarray) -> np.ndarray:
    """Dense doc-term counts for a batch of docs (online-VB pipeline)."""
    out = np.zeros((len(docs), corpus.vocab_size), np.float32)
    for i, doc in enumerate(docs):
        s, l = corpus.doc_start[doc], corpus.doc_len[doc]
        np.add.at(out[i], corpus.w[s:s + l], 1.0)
    return out
