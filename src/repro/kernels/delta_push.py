"""Pallas TPU kernel: count-delta aggregation as one-hot MXU matmuls.

The paper buffers topic reassignments and aggregates the hottest 2000 words
into a local *dense* matrix before pushing (section 3.3), because scatter-add
per reassignment is the bottleneck.  The TPU-native generalisation is to
aggregate *everything* densely on the MXU:

    dn_wk = onehot(w)^T @ (onehot(z_new) - onehot(z_old))     over changed tokens

which turns a scatter (no TPU hardware support) into two one-hot
constructions (VPU compares) and one [TB,V]x[TB,K] matmul (MXU).  +/-1
values are exact in f32, so the int32 result is exact.

  grid        : (V / VB, B / TB), token dim innermost so each vocab block
                accumulates over all token tiles before moving on
  VMEM blocks : tokens [1, TB]; output [VB, Kp] accumulator

Oracle: ``ref.delta_push_ref`` (dense scatter-add).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _delta_kernel(w_ref, zold_ref, znew_ref, chg_ref, out_ref, *,
                  vb: int):
    v_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tb = w_ref.shape[1]
    vb_, kp = out_ref.shape

    w = w_ref[0, :]
    zo = zold_ref[0, :]
    zn = znew_ref[0, :]
    chg = chg_ref[0, :].astype(jnp.float32)

    # one-hot over this vocab block only: local id in [0, VB)
    w_local = w - v_blk * vb
    iota_v = jax.lax.broadcasted_iota(jnp.int32, (tb, vb_), 1)
    onehot_w = jnp.where(iota_v == w_local[:, None], chg[:, None], 0.0)

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tb, kp), 1)
    dz = ((iota_k == zn[:, None]).astype(jnp.float32)
          - (iota_k == zo[:, None]).astype(jnp.float32))

    acc = jax.lax.dot_general(
        onehot_w, dz, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += acc.astype(jnp.int32)


def delta_push_call(w, z_old, z_new, changed, *, vocab_pad: int, k_pad: int,
                    tile_tokens: int = 1024, tile_vocab: int = 512,
                    interpret: bool = True):
    """Aggregate one block of reassignments into a dense [vocab_pad, k_pad]
    int32 delta.  Inputs are [1, B] int32 (``changed`` as int32 mask); B must
    be a multiple of ``tile_tokens``; vocab_pad of ``tile_vocab``; k_pad of
    128 (ops.py maintains this)."""
    b = w.shape[1]
    tb = min(tile_tokens, b)
    vb = min(tile_vocab, vocab_pad)
    assert b % tb == 0 and vocab_pad % vb == 0
    grid = (vocab_pad // vb, b // tb)

    tok = pl.BlockSpec((1, tb), lambda v, t: (0, t))
    out = pl.BlockSpec((vb, k_pad), lambda v, t: (v, 0))

    return pl.pallas_call(
        functools.partial(_delta_kernel, vb=vb),
        grid=grid,
        in_specs=[tok, tok, tok, tok],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((vocab_pad, k_pad), jnp.int32),
        interpret=interpret,
    )(w, z_old, z_new, changed)
