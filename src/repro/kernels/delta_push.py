"""Pallas TPU kernel: count-delta aggregation as one-hot MXU matmuls.

The paper buffers topic reassignments and aggregates the hottest 2000 words
into a local *dense* matrix before pushing (section 3.3), because scatter-add
per reassignment is the bottleneck.  The TPU-native generalisation is to
aggregate *everything* densely on the MXU:

    dn_wk = onehot(w)^T @ (onehot(z_new) - onehot(z_old))     over changed tokens

which turns a scatter (no TPU hardware support) into two one-hot
constructions (VPU compares) and one [TB,V]x[TB,K] matmul (MXU).  +/-1
values are exact in f32, so the int32 result is exact.

  grid        : (V / VB, B / TB), token dim innermost so each vocab block
                accumulates over all token tiles before moving on
  VMEM blocks : tokens [1, TB]; output [VB, Kp] accumulator

Oracle: ``ref.delta_push_ref`` (dense scatter-add).

The *hybrid* path (paper section 3.3 verbatim, rather than generalised)
splits words at a hot/cold boundary ``H``: the top-``H`` hottest words --
frequency-ordered ids, so a logical-id prefix -- aggregate through the dense
one-hot kernel above, while the cold tail is emitted as compressed
``(row, col, +/-1)`` coordinate deltas (``cold_coo``) and applied through
``DistributedMatrix.push_sparse``.  ``delta_apply_coo_call`` is the
server-side Pallas kernel that turns such a coordinate buffer back into a
dense delta with the same one-hot-matmul trick (oracle:
``ref.delta_apply_coo_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _delta_kernel(w_ref, zold_ref, znew_ref, chg_ref, out_ref, *,
                  vb: int):
    v_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tb = w_ref.shape[1]
    vb_, kp = out_ref.shape

    w = w_ref[0, :]
    zo = zold_ref[0, :]
    zn = znew_ref[0, :]
    chg = chg_ref[0, :].astype(jnp.float32)

    # one-hot over this vocab block only: local id in [0, VB)
    w_local = w - v_blk * vb
    iota_v = jax.lax.broadcasted_iota(jnp.int32, (tb, vb_), 1)
    onehot_w = jnp.where(iota_v == w_local[:, None], chg[:, None], 0.0)

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tb, kp), 1)
    dz = ((iota_k == zn[:, None]).astype(jnp.float32)
          - (iota_k == zo[:, None]).astype(jnp.float32))

    acc = jax.lax.dot_general(
        onehot_w, dz, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += acc.astype(jnp.int32)


def delta_push_call(w, z_old, z_new, changed, *, vocab_pad: int, k_pad: int,
                    tile_tokens: int = 1024, tile_vocab: int = 512,
                    interpret: bool = True):
    """Aggregate one block of reassignments into a dense [vocab_pad, k_pad]
    int32 delta.  Inputs are [1, B] int32 (``changed`` as int32 mask); B must
    be a multiple of ``tile_tokens``; vocab_pad of ``tile_vocab``; k_pad of
    128 (ops.py maintains this)."""
    b = w.shape[1]
    tb = min(tile_tokens, b)
    vb = min(tile_vocab, vocab_pad)
    assert b % tb == 0 and vocab_pad % vb == 0
    grid = (vocab_pad // vb, b // tb)

    tok = pl.BlockSpec((1, tb), lambda v, t: (0, t))
    out = pl.BlockSpec((vb, k_pad), lambda v, t: (v, 0))

    return pl.pallas_call(
        functools.partial(_delta_kernel, vb=vb),
        grid=grid,
        in_specs=[tok, tok, tok, tok],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((vocab_pad, k_pad), jnp.int32),
        interpret=interpret,
    )(w, z_old, z_new, changed)


# ---------------------------------------------------------------------------
# Hybrid hot/cold split (paper section 3.3): host-side helpers.
# ---------------------------------------------------------------------------

def split_hot_cold(w, changed, hot_words: int):
    """Partition changed tokens at the hot/cold word boundary.

    Words are frequency-ordered, so logical ids ``< hot_words`` are the
    paper's hottest words (its top-2000 dense buffer).  Returns boolean
    (hot, cold) masks; both imply ``changed``.
    """
    hot = changed & (w < hot_words)
    cold = changed & (w >= hot_words)
    return hot, cold


def cold_coo(w, z_old, z_new, cold_mask):
    """Compress the cold tail into coordinate deltas.

    Each changed cold token emits two entries: ``-1`` at ``(w, z_old)`` and
    ``+1`` at ``(w, z_new)`` -- the per-reassignment message of the paper's
    100k buffer.  Masked-out tokens emit value-0 entries (harmless under
    additive application), keeping shapes static for jit.
    Returns ``(rows [2B], cols [2B], vals [2B])``, all int32.
    """
    m = cold_mask.astype(jnp.int32)
    rows = jnp.concatenate([w, w]).astype(jnp.int32)
    cols = jnp.concatenate([z_old, z_new]).astype(jnp.int32)
    vals = jnp.concatenate([-m, m])
    return rows, cols, vals


# ---------------------------------------------------------------------------
# Sparse coordinate-delta application kernel.
# ---------------------------------------------------------------------------

def _coo_kernel(rows_ref, cols_ref, vals_ref, out_ref, *, vb: int):
    v_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tb = rows_ref.shape[1]
    vb_, kp = out_ref.shape

    r = rows_ref[0, :]
    c = cols_ref[0, :]
    v = vals_ref[0, :].astype(jnp.float32)

    # one-hot over this vocab block only, weighted by the +/-1 value;
    # out-of-block rows (and value-0 padding) match nothing / contribute 0
    r_local = r - v_blk * vb
    iota_v = jax.lax.broadcasted_iota(jnp.int32, (tb, vb_), 1)
    onehot_r = jnp.where(iota_v == r_local[:, None], v[:, None], 0.0)

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tb, kp), 1)
    onehot_c = (iota_k == c[:, None]).astype(jnp.float32)

    acc = jax.lax.dot_general(
        onehot_r, onehot_c, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += acc.astype(jnp.int32)


def delta_apply_coo_call(rows, cols, vals, *, vocab_pad: int, k_pad: int,
                         tile_tokens: int = 1024, tile_vocab: int = 512,
                         interpret: bool = True):
    """Apply a compressed coordinate-delta buffer as a dense
    [vocab_pad, k_pad] int32 delta.  Inputs are [1, M] int32 with value-0
    entries acting as padding; M must be a multiple of ``tile_tokens``,
    vocab_pad of ``tile_vocab``, k_pad of 128 (ops.py maintains this)."""
    m = rows.shape[1]
    tb = min(tile_tokens, m)
    vb = min(tile_vocab, vocab_pad)
    assert m % tb == 0 and vocab_pad % vb == 0
    grid = (vocab_pad // vb, m // tb)

    tok = pl.BlockSpec((1, tb), lambda v, t: (0, t))
    out = pl.BlockSpec((vb, k_pad), lambda v, t: (v, 0))

    return pl.pallas_call(
        functools.partial(_coo_kernel, vb=vb),
        grid=grid,
        in_specs=[tok, tok, tok],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((vocab_pad, k_pad), jnp.int32),
        interpret=interpret,
    )(rows, cols, vals)
