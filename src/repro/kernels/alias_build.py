"""Pallas TPU kernel: Vose alias-table construction for a tile of words.

The sweep rebuilds alias tables for every vocabulary row from the count
snapshot (paper section 3, ref [14]).  Construction is a sequential
two-stack algorithm per row, but it vectorises across the *row* dimension:
this kernel runs the 2K-step stack loop for a [R, K] tile with all per-row
state (residual weights, stacks, counters) held in VMEM/registers.

TPU adaptation: stack pops/pushes become one-hot masked selections over the
K lane dimension (no scatter/gather hardware needed), exactly like the
mh_sample kernel's column selects.  The O(K) cost per step makes the loop
O(K^2) per row -- acceptable because construction is amortized over a whole
block of token resamples (the LightLDA argument), and the row tile keeps
the MXU-adjacent VPU busy across 8-128 rows at once.

Split of labour (mirrors ops.py's pre-gather pattern): the *initial* stack
layout needs an argsort, which XLA does better than a kernel -- ops.py
precomputes (q, small_stack, large_stack, n_small, n_large) and the kernel
runs only the sequential retirement loop.

Padding contract: padded columns carry q == 1.0 exactly and are excluded
from both stacks, so they finish as self-aliased prob-1 buckets that can
never be emitted as an alias target.

Oracle: ``repro.core.alias.build_alias_rows`` -- equality is on the
*induced pmf* (alias assignments are permutation-dependent; the
distribution is not).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _alias_kernel(q_ref, small_ref, large_ref, ns_ref, nl_ref,
                  prob_ref, alias_ref, *, num_cols: int):
    r, kp = q_ref.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (r, kp), 1)

    def col_f(mat, idx):
        """mat[r, idx_r] per row (one-hot masked lane reduction)."""
        return jnp.sum(jnp.where(iota == idx[:, None], mat, 0.0), axis=1)

    def col_i(mat, idx):
        return jnp.sum(jnp.where(iota == idx[:, None], mat, 0), axis=1)

    def set_col_f(mat, idx, val, active):
        hit = (iota == idx[:, None]) & active[:, None]
        return jnp.where(hit, val[:, None], mat)

    def set_col_i(mat, idx, val, active):
        hit = (iota == idx[:, None]) & active[:, None]
        return jnp.where(hit, val[:, None], mat)

    def body(_, state):
        q, prob, alias, small, large, ns, nl = state
        active = (ns > 0) & (nl > 0)
        s_idx = col_i(small, jnp.maximum(ns - 1, 0))
        l_idx = col_i(large, jnp.maximum(nl - 1, 0))
        q_s = col_f(q, s_idx)
        q_l = col_f(q, l_idx)

        prob = set_col_f(prob, s_idx, q_s, active)
        alias = set_col_i(alias, s_idx, l_idx, active)
        q_l_new = q_l + q_s - 1.0
        q = set_col_f(q, l_idx, q_l_new, active)

        ns_after = jnp.where(active, ns - 1, ns)
        demote = active & (q_l_new < 1.0)
        nl = jnp.where(demote, nl - 1, nl)
        small = set_col_i(small, ns_after, l_idx, demote)
        ns = jnp.where(demote, ns_after + 1, ns_after)
        return (q, prob, alias, small, large, ns, nl)

    q = q_ref[...]
    small = small_ref[...]
    large = large_ref[...]
    ns = ns_ref[0, :]
    nl = nl_ref[0, :]
    prob0 = jnp.ones((r, kp), jnp.float32)
    alias0 = iota

    state = (q, prob0, alias0, small, large, ns, nl)
    state = jax.lax.fori_loop(0, 2 * num_cols, body, state)
    _, prob, alias, _, _, _, _ = state
    prob_ref[...] = jnp.clip(prob, 0.0, 1.0)
    alias_ref[...] = alias


def alias_build_call(q, small, large, ns, nl, *, num_cols: int,
                     tile_rows: int = 64, interpret: bool = True):
    """q/small/large: [V, Kp]; ns/nl: [1, V].  Returns (prob, alias)."""
    v, kp = q.shape
    tr = min(tile_rows, v)
    assert v % tr == 0, (v, tr)
    grid = (v // tr,)

    rows = pl.BlockSpec((tr, kp), lambda i: (i, 0))
    cnt = pl.BlockSpec((1, tr), lambda i: (0, i))

    return pl.pallas_call(
        functools.partial(_alias_kernel, num_cols=num_cols),
        grid=grid,
        in_specs=[rows, rows, rows, cnt, cnt],
        out_specs=(rows, rows),
        out_shape=(jax.ShapeDtypeStruct((v, kp), jnp.float32),
                   jax.ShapeDtypeStruct((v, kp), jnp.int32)),
        interpret=interpret,
    )(q, small, large, ns, nl)
