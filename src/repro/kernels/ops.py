"""Jit'd public wrappers around the Pallas kernels.

These handle the padding/layout contract (token-dim multiples of the tile,
K padded to 128 lanes, per-token vectors promoted to [1, B]) and fall back
to the jnp oracles where a kernel does not exist.

``interpret`` is resolved in ONE place -- ``default_interpret()`` -- so a
TPU run flips a single switch instead of touching every signature: every
wrapper takes ``interpret=None`` meaning "the process default", which is
the ``REPRO_INTERPRET`` env var when set (``0``/``false`` compiles,
anything else interprets), else interpret-on-CPU / compiled-on-accelerator.
Explicit ``True``/``False`` still override per call.
"""
from __future__ import annotations

import os
from functools import partial
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.kernels import delta_push as _delta
from repro.kernels import mh_sample as _mh

if TYPE_CHECKING:  # avoid import cycle at runtime
    from repro.core.lightlda import LDAConfig, MHRandoms

LANES = 128  # TPU lane width: K is padded to a multiple of this


def default_interpret() -> bool:
    """The process-wide Pallas interpret default (see module docstring).

    Precedence: ``REPRO_INTERPRET`` env var, else interpret when the JAX
    backend is CPU (kernels cannot compile there) and compile otherwise.
    """
    env = os.environ.get("REPRO_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "")
    return jax.default_backend() == "cpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _pad_axis(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def mh_sample(rng: "MHRandoms", z0, nwk_rows, ndk_rows, nk,
              aprob_rows, aalias_rows, cfg: "LDAConfig", *,
              tile_tokens: int = 1024, interpret: Optional[bool] = None,
              frozen: bool = False) -> jax.Array:
    """Fused MH chain for one block of tokens (kernels/mh_sample.py).

    Accepts the same unpadded [B, K]/[B] arrays as the oracle
    ``lightlda.mh_chain`` and returns [B] int32 new assignments.

    ``frozen=True`` is the inference-mode wrapper used by the serving
    subsystem (repro.infer): same kernel, compiled with the fold-in
    -dw-correction variant (doc counts only), for sampling unseen documents
    against a frozen snapshot.
    """
    interpret = _resolve_interpret(interpret)
    b = z0.shape[0]
    bp = b + ((-b) % tile_tokens)

    def prep_rows(x, fill=0.0):
        x = _pad_axis(x.astype(jnp.float32) if x.dtype != jnp.int32 else x,
                      LANES, axis=1, value=fill)
        return _pad_axis(x, tile_tokens, axis=0)

    nwk_p = prep_rows(nwk_rows.astype(jnp.float32))
    ndk_p = prep_rows(ndk_rows.astype(jnp.float32))
    aprob_p = prep_rows(aprob_rows.astype(jnp.float32))
    aalias_p = prep_rows(aalias_rows)
    nk_p = _pad_axis(nk.astype(jnp.float32)[None, :], LANES, axis=1, value=1.0)

    z0_p = _pad_axis(z0[None, :], tile_tokens, axis=1)
    rand = [_pad_axis(r, tile_tokens, axis=1)
            for r in (rng.u_word, rng.u_waccept, rng.z_doc, rng.u_daccept)]
    # padded tokens: force "never accept" coins (ratio can't exceed 1e30)
    out = _mh.mh_sample_call(
        z0_p, nwk_p, ndk_p, nk_p, aprob_p, aalias_p,
        rand[0], rand[1], rand[2].astype(jnp.int32), rand[3],
        num_topics=cfg.K, vocab_size=cfg.V, alpha=cfg.alpha, beta=cfg.beta,
        mh_steps=cfg.mh_steps, tile_tokens=tile_tokens, interpret=interpret,
        frozen=frozen)
    return out[0, :b]


def delta_push(w, z_old, z_new, changed, vocab_size: int, num_topics: int, *,
               tile_tokens: int = 1024, tile_vocab: int = 512,
               interpret: Optional[bool] = None) -> jax.Array:
    """Dense [V, K] reassignment delta via one-hot MXU matmuls
    (kernels/delta_push.py).  Matches ``ref.delta_push_ref`` exactly."""
    interpret = _resolve_interpret(interpret)
    vb = min(tile_vocab, vocab_size + ((-vocab_size) % 8))
    vp = vocab_size + ((-vocab_size) % vb)
    kp = num_topics + ((-num_topics) % LANES)

    def tok(x):
        return _pad_axis(x.astype(jnp.int32)[None, :], tile_tokens, axis=1)

    # padded tokens have changed=0 and thus contribute nothing
    out = _delta.delta_push_call(
        tok(w), tok(z_old), tok(z_new), tok(changed),
        vocab_pad=vp, k_pad=kp, tile_tokens=tile_tokens, tile_vocab=vb,
        interpret=interpret)
    return out[:vocab_size, :num_topics]


def delta_apply_coo(rows, cols, vals, num_rows: int, num_topics: int, *,
                    tile_tokens: int = 1024, tile_vocab: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Dense [num_rows, num_topics] delta from compressed ``(row, col, +/-1)``
    coordinate entries (kernels/delta_push.py ``_coo_kernel``) -- the server
    side of the hybrid cold-tail push.  Value-0 entries are padding.
    Matches ``ref.delta_apply_coo_ref`` exactly."""
    interpret = _resolve_interpret(interpret)
    vb = min(tile_vocab, num_rows + ((-num_rows) % 8))
    vp = num_rows + ((-num_rows) % vb)
    kp = num_topics + ((-num_topics) % LANES)

    def tok(x):
        return _pad_axis(x.astype(jnp.int32)[None, :], tile_tokens, axis=1)

    # padded entries have vals=0 and thus contribute nothing
    out = _delta.delta_apply_coo_call(
        tok(rows), tok(cols), tok(vals),
        vocab_pad=vp, k_pad=kp, tile_tokens=tile_tokens, tile_vocab=vb,
        interpret=interpret)
    return out[:num_rows, :num_topics]


def alias_build(weights, *, tile_rows: int = 64,
                interpret: Optional[bool] = None) -> "alias_mod.AliasTable":
    """Alias-table construction via the Pallas kernel
    (kernels/alias_build.py).

    ops-side preprocessing (XLA is better at sorts than kernels): scale
    weights to mean 1, build the initial small/large stack layouts with an
    argsort, pad K to the lane width with exactly-1.0 entries (excluded
    from both stacks -> provably never emitted as alias targets) and rows
    to the tile.  The kernel runs the sequential 2K retirement loop.

    Matches ``alias.build_alias_rows`` on the induced pmf (asserted in
    tests; alias assignments themselves are permutation-dependent).
    """
    interpret = _resolve_interpret(interpret)
    v, k = weights.shape
    q = weights.astype(jnp.float32) * (
        k / jnp.maximum(weights.sum(-1, keepdims=True), 1e-30))
    q = _pad_axis(q, LANES, axis=1, value=1.0)
    kp = q.shape[1]
    idx = jnp.arange(kp, dtype=jnp.int32)[None, :]
    is_small = q < 1.0
    is_large = q > 1.0
    # smalls (then larges) packed to the front, ascending
    small = jnp.argsort(jnp.where(is_small, idx, idx + kp),
                        axis=1).astype(jnp.int32)
    large = jnp.argsort(jnp.where(is_large, idx, idx + kp),
                        axis=1).astype(jnp.int32)
    ns = is_small.sum(-1).astype(jnp.int32)
    nl = is_large.sum(-1).astype(jnp.int32)

    vp = v + ((-v) % tile_rows)
    q = _pad_axis(q, tile_rows, axis=0, value=1.0)
    small = _pad_axis(small, tile_rows, axis=0)
    large = _pad_axis(large, tile_rows, axis=0)
    ns = _pad_axis(ns[None, :], tile_rows, axis=1)
    nl = _pad_axis(nl[None, :], tile_rows, axis=1)

    from repro.kernels import alias_build as _ab
    prob, alias_idx = _ab.alias_build_call(
        q, small, large, ns, nl, num_cols=k, tile_rows=tile_rows,
        interpret=interpret)
    return alias_mod.AliasTable(prob[:v, :k], alias_idx[:v, :k])
