"""Pallas TPU kernel: fused LightLDA Metropolis-Hastings chain.

The per-token proposal/acceptance chain is the sampler's compute hot-spot
(billions of tokens per iteration in the paper).  The host-side ``ops.py``
wrapper pre-gathers each token's count/alias rows (the "pull"), so this
kernel is *pure vector compute* on VMEM-resident tiles:

  grid        : (B / TB,) token tiles
  VMEM blocks : [TB, Kp] count/alias rows, [S, TB] pre-drawn randoms,
                [1, TB] assignments -- Kp is K padded to a multiple of 128
                so the one-hot selections land on VPU lanes.

TPU adaptation (DESIGN.md section 2): a GPU implementation would thread one
token per lane with random gathers; on TPU every "gather a column per row"
becomes a one-hot masked reduction over the K lane dimension, which is a
dense [TB, Kp] vector op -- no scatter/gather hardware needed, and the same
trick serves nk lookups.  ``mh_steps`` is unrolled (it is 2-4 in practice).

Padding contract (maintained by ops.py): proposals (alias entries and
pre-drawn doc draws) are always < K, so the padded columns K..Kp-1 are never
selected by any one-hot; their contents are irrelevant.

Oracle: ``repro.core.lightlda.mh_chain`` (also re-exported in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mh_kernel(z0_ref, nwk_ref, ndk_ref, nk_ref, aprob_ref, aalias_ref,
               uw_ref, uwa_ref, zd_ref, uda_ref, out_ref, *,
               num_topics: int, alpha: float, beta: float, vbeta: float,
               mh_steps: int, frozen: bool = False):
    tb, kp = nwk_ref.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tb, kp), 1)

    z0 = z0_ref[0, :]
    nwk = nwk_ref[...]
    ndk = ndk_ref[...]
    nk = nk_ref[0, :]
    aprob = aprob_ref[...]
    aalias = aalias_ref[...]

    def col(mat, k):
        """Select column k_i of row i as a masked lane reduction."""
        return jnp.sum(jnp.where(iota == k[:, None], mat, 0.0), axis=1)

    def nk_at(k):
        return jnp.sum(jnp.where(iota == k[:, None], nk[None, :], 0.0), axis=1)

    def p(k):
        # collapsed posterior factors with the -dw correction (w.r.t. z0);
        # frozen (fold-in) mode corrects only the local doc counts -- the
        # snapshot never contained this document's tokens.
        e = (k == z0).astype(jnp.float32)
        e_wk = 0.0 if frozen else e
        return ((col(ndk, k) - e + alpha) * (col(nwk, k) - e_wk + beta)
                / (nk_at(k) - e_wk + vbeta))

    def q_word(k):
        return (col(nwk, k) + beta) / (nk_at(k) + vbeta)

    def q_doc(k):
        return col(ndk, k) + alpha

    z = z0
    for s in range(mh_steps):
        # ---- word proposal via alias table (single-uniform trick) ----
        scaled = uw_ref[s, :] * num_topics
        bucket = jnp.minimum(scaled.astype(jnp.int32), num_topics - 1)
        coin = scaled - bucket.astype(jnp.float32)
        pa = col(aprob, bucket)
        al = col(aalias.astype(jnp.float32), bucket).astype(jnp.int32)
        z_prop = jnp.where(coin < pa, bucket, al)
        ratio = (p(z_prop) * q_word(z)) / (
            jnp.maximum(p(z), 1e-30) * jnp.maximum(q_word(z_prop), 1e-30))
        z = jnp.where(uwa_ref[s, :] < ratio, z_prop, z)

        # ---- doc proposal (pre-drawn; independent of chain state) ----
        z_prop = zd_ref[s, :]
        ratio = (p(z_prop) * q_doc(z)) / (
            jnp.maximum(p(z), 1e-30) * jnp.maximum(q_doc(z_prop), 1e-30))
        z = jnp.where(uda_ref[s, :] < ratio, z_prop, z)

    out_ref[0, :] = z


def mh_sample_call(z0, nwk_rows, ndk_rows, nk, aprob, aalias,
                   u_word, u_waccept, z_doc, u_daccept, *,
                   num_topics: int, vocab_size: int, alpha: float,
                   beta: float, mh_steps: int, tile_tokens: int = 1024,
                   interpret: bool = True, frozen: bool = False):
    """pallas_call wrapper (see module docstring for the layout contract).

    ``frozen=True`` compiles the inference-mode chain (fold-in against a
    frozen snapshot; -dw correction on doc counts only)."""
    b = z0.shape[1]
    kp = nwk_rows.shape[1]
    tb = min(tile_tokens, b)
    assert b % tb == 0, (b, tb)
    grid = (b // tb,)

    kern = functools.partial(
        _mh_kernel, num_topics=num_topics, alpha=alpha, beta=beta,
        vbeta=vocab_size * beta, mh_steps=mh_steps, frozen=frozen)

    tok1 = pl.BlockSpec((1, tb), lambda i: (0, i))     # [1, B] per-token
    rows = pl.BlockSpec((tb, kp), lambda i: (i, 0))    # [B, Kp] row blocks
    full = pl.BlockSpec((1, kp), lambda i: (0, 0))     # replicated nk
    rand = pl.BlockSpec((mh_steps, tb), lambda i: (0, i))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[tok1, rows, rows, full, rows, rows, rand, rand, rand, rand],
        out_specs=tok1,
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.int32),
        interpret=interpret,
    )(z0, nwk_rows, ndk_rows, nk, aprob, aalias,
      u_word, u_waccept, z_doc, u_daccept)
