"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each kernel in this package has a reference implementation here with
identical semantics; tests sweep shapes/dtypes and assert allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import lightlda as lda


def mh_sample_ref(rng: "lda.MHRandoms", z0, nwk_rows, ndk_rows, nk,
                  aprob_rows, aalias_rows, cfg: "lda.LDAConfig") -> jax.Array:
    """Oracle for kernels/mh_sample.py: the vectorised MH chain."""
    return lda.mh_chain(rng, z0, nwk_rows, ndk_rows, nk,
                        aprob_rows, aalias_rows, cfg)


def delta_push_ref(w, z_old, z_new, changed, vocab_size: int,
                   num_topics: int) -> jax.Array:
    """Oracle for kernels/delta_push.py: dense scatter-add aggregation."""
    amt = changed.astype(jnp.int32)
    return (jnp.zeros((vocab_size, num_topics), jnp.int32)
            .at[w, z_old].add(-amt)
            .at[w, z_new].add(amt))


def delta_apply_coo_ref(rows, cols, vals, num_rows: int,
                        num_topics: int) -> jax.Array:
    """Oracle for kernels/delta_push.py ``_coo_kernel``: scatter-add of
    compressed (row, col, +/-1) coordinate deltas (value-0 entries are
    padding and contribute nothing)."""
    return (jnp.zeros((num_rows, num_topics), jnp.int32)
            .at[rows, cols].add(vals.astype(jnp.int32)))


def alias_build_ref(weights) -> "alias_mod.AliasTable":
    """Oracle for kernels/alias_build.py: exact Vose construction."""
    return alias_mod.build_alias_rows(weights)
