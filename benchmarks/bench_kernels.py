"""Kernel microbenchmarks + the paper's central O(1)-vs-O(K) claim.

1. mh_sample / delta_push Pallas kernels vs their jnp oracles
   (interpret=True on CPU -- correctness-path timing; on a TPU pass
   interpret=False for hardware numbers).
2. Amortized O(1) sampling (alias + MH) vs O(K) full-conditional collapsed
   Gibbs: per-token cost as K grows.  LightLDA's whole point (paper
   section 3) is the flat curve.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alias as alias_mod
from repro.core import lightlda as lda
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _time(fn, *args, iters=5, **kwargs):
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_kernel_vs_ref(fast: bool = False):
    b, k, v = (4096, 64, 1000) if fast else (16384, 128, 5000)
    cfg = lda.LDAConfig(num_topics=k, vocab_size=v, mh_steps=2)
    key = jax.random.PRNGKey(0)
    inp = {}
    ks = jax.random.split(key, 11)
    inp["z0"] = jax.random.randint(ks[0], (b,), 0, k, dtype=jnp.int32)
    inp["nwk_rows"] = jax.random.randint(ks[1], (b, k), 0, 100)
    inp["ndk_rows"] = jax.random.randint(ks[2], (b, k), 0, 30)
    inp["nk"] = jax.random.randint(ks[3], (k,), 50, 10000)
    inp["aprob_rows"] = jax.random.uniform(ks[4], (b, k))
    inp["aalias_rows"] = jax.random.randint(ks[5], (b, k), 0, k,
                                            dtype=jnp.int32)
    rng = lda.MHRandoms(jax.random.uniform(ks[6], (2, b)),
                        jax.random.uniform(ks[7], (2, b)),
                        jax.random.randint(ks[8], (2, b), 0, k,
                                           dtype=jnp.int32),
                        jax.random.uniform(ks[9], (2, b)))

    ref_t = _time(jax.jit(lambda r, **kw: kref.mh_sample_ref(r, cfg=cfg, **kw)),
                  rng, **inp)
    ker_t = _time(jax.jit(lambda r, **kw: kops.mh_sample(r, cfg=cfg, **kw)),
                  rng, **inp)
    print(f"kernels,mh_sample_ref,{ref_t:.0f},us_per_block")
    print(f"kernels,mh_sample_pallas_interpret,{ker_t:.0f},us_per_block")

    w = jax.random.randint(ks[10], (b,), 0, v, dtype=jnp.int32)
    zn = jax.random.randint(ks[0], (b,), 0, k, dtype=jnp.int32)
    chg = inp["z0"] != zn
    ref_t = _time(jax.jit(lambda: kref.delta_push_ref(w, inp["z0"], zn, chg,
                                                      v, k)))
    ker_t = _time(jax.jit(lambda: kops.delta_push(w, inp["z0"], zn, chg,
                                                  v, k)))
    print(f"kernels,delta_push_ref,{ref_t:.0f},us_per_block")
    print(f"kernels,delta_push_pallas_interpret,{ker_t:.0f},us_per_block")


def bench_o1_vs_ok(fast: bool = False):
    """Per-token sampling cost: MH-alias (O(1)) vs full conditional (O(K))."""
    b = 8192
    v = 500
    rows = []
    for k in ([64, 256] if fast else [32, 128, 512, 2048]):
        key = jax.random.PRNGKey(k)
        ks = jax.random.split(key, 8)
        nwk_rows = jax.random.randint(ks[0], (b, k), 0, 50).astype(jnp.float32)
        ndk_rows = jax.random.randint(ks[1], (b, k), 0, 20).astype(jnp.float32)
        nk = jax.random.randint(ks[2], (k,), 100, 10_000).astype(jnp.float32)
        z0 = jax.random.randint(ks[3], (b,), 0, k, dtype=jnp.int32)
        cfg = lda.LDAConfig(num_topics=k, vocab_size=v, mh_steps=2)
        aprob = jax.random.uniform(ks[4], (b, k))
        aalias = jax.random.randint(ks[5], (b, k), 0, k, dtype=jnp.int32)
        rng = lda.MHRandoms(jax.random.uniform(ks[6], (2, b)),
                            jax.random.uniform(ks[7], (2, b)),
                            jax.random.randint(ks[6], (2, b), 0, k,
                                               dtype=jnp.int32),
                            jax.random.uniform(ks[7], (2, b)))

        def mh():
            return lda.mh_chain(rng, z0, nwk_rows, ndk_rows, nk, aprob,
                                aalias, cfg)

        def full_conditional():
            # O(K): materialise the full posterior row per token and sample
            p = (ndk_rows + cfg.alpha) * (nwk_rows + cfg.beta) / (
                nk[None, :] + v * cfg.beta)
            return jax.random.categorical(jax.random.PRNGKey(0),
                                          jnp.log(p + 1e-30), axis=-1)

        t_mh = _time(jax.jit(mh)) / b * 1e3     # ns/token
        t_fc = _time(jax.jit(full_conditional)) / b * 1e3
        rows.append((k, t_mh, t_fc))
        print(f"kernels,sampling_cost,K={k},mh_ns_per_token={t_mh:.1f},"
              f"fullcond_ns_per_token={t_fc:.1f}")
    # the O(K) cost must grow much faster than the amortized-O(1) MH cost
    # NOTE: mh_chain still *gathers* pre-pulled K-rows, so its vectorised
    # cost is not perfectly flat on CPU; the ratio is the measurement.
    k0, mh0, fc0 = rows[0]
    k1, mh1, fc1 = rows[-1]
    print(f"kernels,sampling_growth,K={k0}->{k1},"
          f"mh_x{mh1/max(mh0,1e-9):.1f},fullcond_x{fc1/max(fc0,1e-9):.1f}")


def main(fast: bool = False):
    bench_kernel_vs_ref(fast)
    bench_o1_vs_ok(fast)


if __name__ == "__main__":
    main()
