"""Serving-under-load benchmark: concurrent admission + live refresh.

The "millions of users" axis made measurable (DESIGN.md section 14): K
concurrent client threads submit Zipf-length documents through the
``ConcurrentEngine`` admission queue while a background trainer keeps
publishing fresh snapshots through the ``SnapshotPublisher`` -- the full
production loop: train, publish, and serve at the same time.

Reports QPS and p50/p95/p99 request latency from the existing
``serve.request_ms`` histograms, the dual-trigger mix (full vs timeout
flushes), and the number of zero-downtime snapshot swaps that landed
under load.  Hard acceptance (asserted after the JSON is written):

  * >= MIN_SWAPS snapshot swaps while clients were in flight;
  * zero lost non-shed requests: every submitted request either returned
    a ``Result`` or raised a typed ``DeadlineExceeded`` -- nothing
    dropped, nothing wedged;
  * deadline-shed requests surface as typed errors and are counted by
    the ``serve.shed`` counter.

Writes ``experiments/bench/BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np

from repro import obs as _obs
from repro.core import lightlda as lda
from repro.data import corpus as corpus_mod
from repro.infer.engine import DeadlineExceeded, EngineConfig
from repro.infer.foldin import FoldInConfig
from repro.serve.topic_service import TopicService

OUT = "experiments/bench/BENCH_serve.json"
OBS_DIR = "experiments/bench/serve_obs"
MIN_SWAPS = 5        # zero-downtime swaps that must land under load
CLIENTS = 8
MAX_WAVES = 60       # per-client cap on extra waves while awaiting swaps


def _service(fast: bool):
    docs, vocab, k, sweeps = ((300, 500, 12, 6) if fast
                              else (1000, 2000, 32, 15))
    corp = corpus_mod.synthetic_corpus(docs, vocab, true_topics=8,
                                       mean_doc_len=50, seed=0)
    cfg = lda.LDAConfig(num_topics=k, vocab_size=vocab, block_tokens=4096)
    ecfg = EngineConfig(max_batch=16, max_delay_ms=3.0,
                        foldin=FoldInConfig(num_sweeps=8, burnin=3))
    svc = TopicService(cfg, ecfg)
    svc.init_from_corpus(corp, seed=0)
    svc.train(sweeps, jax.random.PRNGKey(1), publish_every=0)
    return svc, vocab


def _zipf_doc(rng, vocab: int, max_len: int = 256) -> np.ndarray:
    """One Zipf-length request document (heavy-tailed, like real queries:
    mostly short, occasionally long enough to land in a big bucket)."""
    n = int(min(3 + rng.zipf(1.4), max_len))
    return rng.integers(0, vocab, size=n).astype(np.int32)


def main(fast: bool = False):
    per_client = 16 if fast else 48
    wave = 4                      # tickets in flight per client at a time
    svc, vocab = _service(fast)

    session = _obs.ObsSession(_obs.ObsConfig(
        enabled=True, trace=False, out_dir=OBS_DIR)).install()
    try:
        # warm the per-bucket jit cache off the clock: one flush per bucket
        rng = np.random.default_rng(99)
        svc.fold_in([rng.integers(0, vocab, size=n).astype(np.int32)
                     for n in (8, 20, 40, 90, 200)])

        svc.start_serving()
        v0 = svc.version
        stop_training = threading.Event()

        def trainer():
            # one publish per loop turn; keep refreshing while clients are
            # in flight, and never stop before MIN_SWAPS swaps have landed
            i = 0
            while not stop_training.is_set() or svc.version - v0 < MIN_SWAPS:
                svc.train(1, jax.random.PRNGKey(1000 + i), publish_every=0)
                i += 1

        lock = threading.Lock()
        served, shed, errors = [], [], []

        def client(ci: int) -> None:
            rng = np.random.default_rng(500 + ci)
            sent = 0
            waves = 0
            # keep the load up (in waves) until this client has pushed its
            # quota AND enough live swaps have happened underneath it
            while (sent < per_client
                   or (svc.version - v0 < MIN_SWAPS and waves < MAX_WAVES)):
                tickets = [svc.submit(_zipf_doc(rng, vocab),
                                      seed=ci * 100_000 + sent + i)
                           for i in range(wave)]
                sent += wave
                waves += 1
                for t in tickets:
                    try:
                        r = t.result(timeout=300)
                        with lock:
                            served.append(r)
                    except Exception as exc:  # noqa: BLE001 -- verdict below
                        with lock:
                            errors.append(exc)
            with lock:
                submitted[ci] = sent

        submitted = [0] * CLIENTS
        train_thread = threading.Thread(target=trainer, daemon=True)
        train_thread.start()
        t0 = time.time()
        threads = [threading.Thread(target=client, args=(ci,), daemon=True)
                   for ci in range(CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.time() - t0
        stop_training.set()
        train_thread.join()
        swaps = svc.version - v0

        # typed-shed demonstration: already-expired deadlines must surface
        # as DeadlineExceeded, never as lost requests or other errors
        shed_wave = [svc.submit(_zipf_doc(np.random.default_rng(7), vocab),
                                seed=10_000_000 + i, deadline_ms=0.001)
                     for i in range(8)]
        for t in shed_wave:
            try:
                r = t.result(timeout=300)
                with lock:
                    served.append(r)    # raced past its deadline: served
            except DeadlineExceeded as exc:
                shed.append(exc)
            except Exception as exc:  # noqa: BLE001 -- verdict below
                errors.append(exc)
        svc.stop_serving()

        reg = _obs.metrics_registry()
        hist = reg.get("serve.request_ms")
        lat = hist.summary() if hist is not None else {}
        trig = {name.rsplit(".", 1)[-1]: c.value
                for name, c in reg.all().items()
                if name.startswith("serve.batch_trigger.")}
        shed_counter = reg.get("serve.shed")
        lag = reg.get("serve.version_lag")
    finally:
        session.close(save=True)

    total = sum(submitted) + len(shed_wave)
    qps = len(served) / dt
    versions = sorted({r.version for r in served})
    print(f"serve,clients,{CLIENTS},requests,{total}")
    print(f"serve,qps,{qps:.1f},served,{len(served)},shed,{len(shed)},"
          f"errors,{len(errors)}")
    print(f"serve,latency_ms,p50,{lat.get('p50', 0):.2f},"
          f"p95,{lat.get('p95', 0):.2f},p99,{lat.get('p99', 0):.2f}")
    print(f"serve,swaps_under_load,{swaps},versions_served,"
          f"{versions[0]}..{versions[-1]}")
    print(f"serve,batch_trigger,{json.dumps(trig, sort_keys=True)}")

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({
            "config": {"clients": CLIENTS, "per_client": per_client,
                       "vocab": vocab, "K": svc.cfg.K,
                       "max_batch": svc.ecfg.max_batch,
                       "max_delay_ms": svc.ecfg.max_delay_ms,
                       "foldin_sweeps": svc.ecfg.foldin.num_sweeps},
            "requests": total,
            "served": len(served),
            "shed": len(shed),
            "errors": len(errors),
            "qps": qps,
            "latency_ms": {k: lat.get(k, 0.0)
                           for k in ("p50", "p90", "p95", "p99", "mean",
                                     "max", "count")},
            "snapshot_swaps_under_load": swaps,
            "versions_served": versions,
            "version_lag_last": lag.value if lag is not None else None,
            "batch_trigger": trig,
            "shed_counter": shed_counter.value
            if shed_counter is not None else 0,
        }, f, indent=2)
    print(f"serve,wrote,{OUT}")

    assert not errors, f"non-typed serving failures: {errors[:3]}"
    assert len(served) + len(shed) == total, (
        f"lost requests: {total - len(served) - len(shed)}")
    assert swaps >= MIN_SWAPS, f"only {swaps} swaps under load"
    assert len(shed) == (shed_counter.value if shed_counter else 0), (
        "serve.shed counter disagrees with typed DeadlineExceeded count")


if __name__ == "__main__":
    main(fast=True)
