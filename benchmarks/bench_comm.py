"""Paper Table 1 'Shuffle write' column, structurally: per-iteration
communication volume of the three architectures as a function of workers
and K.

  * lightlda-ps : parsed from the *compiled HLO* of the distributed sweep
    (the real collectives the SPMD program executes), per worker.
  * spark-em    : GraphX shuffle model, 2 K-float messages per token.
  * spark-online: lambda [K, V] broadcast per minibatch per worker.

This is the communication analysis that explains the paper's zero-shuffle
column; it runs the actual shard_map lowering on fake host devices.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from repro.core import lda_em as em

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def ps_bytes_from_hlo(workers: int, model: int, vocab: int, k: int,
                      tokens: int) -> dict:
    """Compile the distributed sweep on fake devices in a subprocess and
    parse its collective bytes."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={workers}"
        import jax, jax.numpy as jnp, numpy as np, json
        from repro import ps
        from repro.core import lightlda as lda
        from repro.data import corpus as corpus_mod
        from repro.launch import lda as L
        from repro.analysis import hlo_stats as H

        corp = corpus_mod.synthetic_corpus(300, {vocab}, true_topics=8,
            mean_doc_len={max(tokens // 300, 8)}, seed=0)
        cfg = lda.LDAConfig(num_topics={k}, vocab_size={vocab},
                            block_tokens=1024, num_shards={model})
        data = {workers} // {model}
        mesh = jax.make_mesh((data, {model}), ("data", "model"))
        fn = L.make_spmd_sweep(mesh, cfg)
        shards = corpus_mod.shard_tokens(corp, {workers}, cfg.block_tokens)
        npad = max(s[0].shape[0] for s in shards)
        dmax = max(s[3].shape[0] for s in shards)
        def sds(shape, dt): return jax.ShapeDtypeStruct(shape, dt)
        W = {workers}
        lowered = jax.jit(fn).lower(
            sds((W, npad), jnp.int32), sds((W, npad), jnp.int32),
            sds((W, npad), jnp.int32), sds((W, npad), jnp.bool_),
            sds((W, dmax), jnp.int32), sds((W, dmax), jnp.int32),
            sds((W, dmax, cfg.K), jnp.int32),
            sds((ps.client_for(cfg).matrix(cfg.V, cfg.K).value.shape), jnp.int32),
            sds((cfg.K,), jnp.int32), sds((W, 2), jnp.uint32))
        st = H.analyze_text(lowered.compile().as_text())
        print(json.dumps(dict(wire=st.coll_wire_bytes,
                              counts={{k2: v for k2, v in st.coll_counts.items() if v}})))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    import json
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(fast: bool = False):
    vocab, k, tokens = (800, 20, 30_000) if fast else (2000, 50, 100_000)
    for workers, model in ([(8, 2)] if fast else [(4, 2), (8, 2), (8, 4)]):
        ps = ps_bytes_from_hlo(workers, model, vocab, k, tokens)
        em_bytes = em.shuffle_bytes_per_iter(
            tokens, em.EMConfig(num_topics=k, vocab_size=vocab))
        online_bytes = k * vocab * 4 * workers
        print(f"comm,workers={workers},servers={model},K={k},"
              f"ps_wire_per_worker={ps['wire']/1e6:.2f}MB,"
              f"em_shuffle={em_bytes/1e6:.2f}MB,"
              f"online_broadcast={online_bytes/1e6:.2f}MB,"
              f"ps_collectives={ps['counts']}")
    return True


if __name__ == "__main__":
    main()
