"""PS client route microbenchmark: dense vs COO vs tuned-hybrid push.

Pushes identical Zipfian reassignment batches through the ``repro.ps``
route machinery (paper section 3.3: the hot/cold boundary is a
traffic-shape knob, never a semantic one) and measures each route's two
halves separately, the way the paper's pipeline pays for them:

  * ``plan_ms``       -- the *worker-side split* (dense aggregation /
                         COO compression), which the paper amortises
                         into the sampling sweep;
  * ``pushes_per_s``  -- the *server-side apply* (``push_plan``: prefix
                         add + cold scatter), the contended resource a
                         parameter server actually serialises on.  This
                         is the headline rate;
  * ``roundtrip_per_s`` -- plan + merge + apply end to end
                         (``MatrixHandle.push``), for reference.

The hybrid runs at the boundary the measured-cost autotuner
(``ps.autotune``) picks for this batch's word frequencies, on a batch
pre-partitioned at that boundary (``ps.partition_reassign``) -- the fixed
regression: its dense block stays [H, K] (never padded to [V, K]) and its
cold buffer is sized to the tail.  Route invariance (every route,
partitioned or not, lands on the bitwise-identical matrix) is asserted
before timing.

Perf gate: tuned-hybrid apply pushes/s must be >= RATCHET x pure-COO
apply pushes/s (the regression this module exists to hold down); the
ratchet and verdict are recorded in ``experiments/bench/BENCH_ps.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import ps
from repro.obs import time_loop
from repro.ps import autotune

OUT = "experiments/bench/BENCH_ps.json"
RATCHET = 0.9    # tuned-hybrid apply rate must be >= RATCHET x pure-COO


def _zipf_reassign(v: int, k: int, batch: int, seed: int) -> ps.Reassign:
    """Reassignment batch with Zipfian word ids (frequency-ordered, like
    the corpus pipeline) so hybrid hot/cold boundaries bite."""
    rng = np.random.default_rng(seed)
    u = rng.random(batch)
    w = np.minimum((u ** -1.05 - 1).astype(np.int64), v - 1).astype(np.int32)
    z0 = rng.integers(0, k, size=batch).astype(np.int32)
    z1 = rng.integers(0, k, size=batch).astype(np.int32)
    changed = rng.random(batch) < 0.6
    w = jnp.asarray(w)
    return ps.Reassign(rows=w, words=w, z_old=jnp.asarray(z0),
                       z_new=jnp.asarray(z1), changed=jnp.asarray(changed))


def main(fast: bool = False):
    v, k, batch = (2000, 64, 16384) if fast else (8000, 128, 65536)
    iters = 20 if fast else 30
    client = ps.PSClient.create(num_shards=8)
    re = _zipf_reassign(v, k, batch, seed=0)

    # --- autotuned hot-word boundary for THIS batch's word mass ---
    _, tune_report = autotune.autotune_route(
        re.words, None, v, k, num_shards=8, batch=batch, shortlist=4,
        iters=max(iters // 4, 3), seed=0)
    hybrids = [r for r in tune_report["measured"]
               if r["hot_words"] is not None]
    hot = (min(hybrids, key=lambda r: r["apply_ms"])["hot_words"]
           if hybrids else max(v // 8, 1))
    print(f"ps,config,V={v},K={k},batch={batch},hot={hot},"
          f"autotune_chose={tune_report['chosen_route']}")

    routes = {
        "dense": ps.DenseRoute(),
        "coo": ps.CooRoute(),
        "hybrid": ps.HybridRoute(hot_words=hot),
    }

    # --- route invariance first: all routes (partitioned or not) must
    # land on the same matrix ---
    base = client.matrix(v, k)
    finals = {name: np.asarray(base.with_route(r).push(re).to_dense())
              for name, r in routes.items()}
    re_part, hp = ps.partition_reassign(re, hot)
    finals["hybrid_partitioned"] = np.asarray(
        base.with_route(routes["hybrid"]).push(re_part, hot_prefix=hp)
        .to_dense())
    ref = finals["dense"]
    for name, got in finals.items():
        np.testing.assert_array_equal(got, ref,
                                      err_msg=f"route {name} diverged")
    print("ps,route_invariance,ok")

    results = {}
    for name, route in routes.items():
        h = base.with_route(route)
        if name == "hybrid":
            re_r, hp_r = re_part, hp
        else:
            re_r, hp_r = re, None

        plan_fn = jax.jit(lambda r, _rt=route, _hp=hp_r: _rt.plan(
            r, v, k, prefix_rows=True, hot_prefix=_hp))
        plan = jax.block_until_ready(plan_fn(re_r))
        _, t_plan = time_loop(lambda _c, i, f=plan_fn: f(re_r), None, iters,
                              label=f"ps_plan_{name}")

        apply_fn = jax.jit(lambda hh, p: hh.push_plan(p))
        _, t_apply = time_loop(lambda hh, i, f=apply_fn: f(hh, plan), h,
                               iters, sync=lambda hh: hh.value,
                               label=f"ps_apply_{name}")

        step = jax.jit(lambda hh, rr, _hp=hp_r: hh.push(rr, hot_prefix=_hp))
        _, t_rt = time_loop(lambda hh, i: step(hh, re_r), h, iters,
                            sync=lambda hh: hh.value,
                            label=f"ps_push_{name}")

        results[name] = {
            "label": route.label,
            "hot_words": getattr(route, "hot_words", None),
            "hot_prefix": hp_r,
            "plan_ms": t_plan.ms_per_iter(),
            "pushes_per_s": t_apply.best_rate(),          # server apply
            "roundtrip_per_s": t_rt.best_rate(),
            "reassign_per_s": t_apply.best_rate(batch),
            "traffic": {kk: int(vv) for kk, vv in route.traffic(
                batch, v, k, hot_prefix=hp_r).items()},
        }
        print(f"ps,route_{name},{t_apply.best_rate():.1f},apply_pushes_per_s,"
              f"{t_plan.ms_per_iter():.3f},plan_ms,"
              f"{t_rt.best_rate():.1f},roundtrip_per_s")

    gate_ok = (results["hybrid"]["pushes_per_s"]
               >= RATCHET * results["coo"]["pushes_per_s"])
    gate = {
        "ratchet": RATCHET,
        "hybrid_pushes_per_s": results["hybrid"]["pushes_per_s"],
        "coo_pushes_per_s": results["coo"]["pushes_per_s"],
        "ok": bool(gate_ok),
    }
    print(f"ps,perf_gate,{'ok' if gate_ok else 'FAIL'},"
          f"hybrid={gate['hybrid_pushes_per_s']:.1f},"
          f"coo={gate['coo_pushes_per_s']:.1f},ratchet={RATCHET}")

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({
            "config": {"V": v, "K": k, "batch": batch, "hot_words": hot,
                       "iters": iters},
            "autotune": tune_report,
            "routes": results,
            "gate": gate,
        }, f, indent=2)
    print(f"ps,wrote,{OUT}")
    assert gate_ok, (
        f"perf gate: tuned-hybrid apply {gate['hybrid_pushes_per_s']:.1f} "
        f"pushes/s < {RATCHET} x pure-COO "
        f"{gate['coo_pushes_per_s']:.1f} pushes/s")


if __name__ == "__main__":
    main(fast=True)
