"""PS client route microbenchmark: dense vs COO vs hybrid push.

Pushes identical Zipfian reassignment batches through ``MatrixHandle.push``
under each ``PushRoute`` (paper section 3.3: the hot/cold boundary is a
traffic-shape knob, never a semantic one) and measures pushes/sec and
reassignments/sec.  Verifies first that every route lands on the bitwise-
identical matrix -- the invariance the whole route design rests on -- then
times the jitted push path per route (``repro.obs.time_loop``, the shared
benchmark methodology).  Writes ``experiments/bench/BENCH_ps.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import ps
from repro.obs import time_loop

OUT = "experiments/bench/BENCH_ps.json"


def _zipf_reassign(v: int, k: int, batch: int, seed: int) -> ps.Reassign:
    """Reassignment batch with Zipfian word ids (frequency-ordered, like
    the corpus pipeline) so hybrid hot/cold boundaries bite."""
    rng = np.random.default_rng(seed)
    u = rng.random(batch)
    w = np.minimum((u ** -1.05 - 1).astype(np.int64), v - 1).astype(np.int32)
    z0 = rng.integers(0, k, size=batch).astype(np.int32)
    z1 = rng.integers(0, k, size=batch).astype(np.int32)
    changed = rng.random(batch) < 0.6
    w = jnp.asarray(w)
    return ps.Reassign(rows=w, words=w, z_old=jnp.asarray(z0),
                       z_new=jnp.asarray(z1), changed=jnp.asarray(changed))


def main(fast: bool = False):
    v, k, batch = (2000, 64, 16384) if fast else (8000, 128, 65536)
    iters = 20 if fast else 30
    hot = max(v // 8, 1)
    routes = {
        "dense": ps.DenseRoute(),
        "coo": ps.CooRoute(),
        "hybrid": ps.HybridRoute(hot_words=hot),
    }
    client = ps.PSClient.create(num_shards=8)
    re = _zipf_reassign(v, k, batch, seed=0)
    print(f"ps,config,V={v},K={k},batch={batch},hot={hot}")

    # --- route invariance first: all routes must land on the same matrix
    base = client.matrix(v, k)
    finals = {name: np.asarray(base.with_route(r).push(re).to_dense())
              for name, r in routes.items()}
    ref = finals["dense"]
    for name, got in finals.items():
        np.testing.assert_array_equal(got, ref,
                                      err_msg=f"route {name} diverged")
    print("ps,route_invariance,ok")

    results = {}
    for name, route in routes.items():
        h = base.with_route(route)
        step = jax.jit(lambda hh, rr: hh.push(rr))
        _, tm = time_loop(lambda hh, i: step(hh, re), h, iters,
                          sync=lambda hh: hh.value, label=f"ps_push_{name}")
        results[name] = {
            "pushes_per_s": tm.best_rate(),
            "reassign_per_s": tm.best_rate(batch),
        }
        print(f"ps,route_{name},{tm.best_rate():.1f},pushes_per_s,"
              f"{tm.best_rate(batch):,.0f},reassign_per_s")

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({
            "config": {"V": v, "K": k, "batch": batch, "hot_words": hot,
                       "iters": iters},
            "routes": results,
        }, f, indent=2)
    print(f"ps,wrote,{OUT}")


if __name__ == "__main__":
    main(fast=True)
