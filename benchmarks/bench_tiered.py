"""Tiered-storage benchmark: vocabulary past the device budget.

The claim under test is the tentpole's: with ``storage="tiered"`` a model
whose full ``[V, K]`` table is >= 8x a device byte budget trains end to
end while the device never holds more than the budget -- a hot-row cache
(``ps/tiered.py``) over the host memmap cold tier -- and, because word
traffic is Zipfian, >= 90% of changed assignments land on device-resident
rows.

Protocol:
  * geometry: full table ``V*K*4`` bytes == 8x the device budget; the
    hot tier (``hot_rows*K*4``) plus the executor's two block pull
    buffers must fit inside the budget;
  * child process (clean RSS, same technique as bench_stream): draw a
    Zipf(1.5) corpus, train ``APSLDA(job).fit()`` with
    ``storage="tiered"`` and obs metrics on, sample VmRSS throughout,
    then report the ``ps.tier.*`` / ``exec.tiered.device_table_bytes``
    gauges from metrics.jsonl as one JSON line;
  * parent asserts the acceptance gates: table >= 8x budget, peak
    device-table bytes <= budget, hit rate >= 0.9.

Writes ``experiments/bench/BENCH_tiered.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

OUT = "experiments/bench/BENCH_tiered.json"
MiB = 2 ** 20


def _rss_bytes() -> int:
    """Current VmRSS from /proc (Linux); 0 when unavailable."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return 0


def _zipf_docs(rng: np.random.Generator, total_tokens: int,
               vocab: int) -> list:
    """Zipf(1.5) word ids split into ~192-token docs.

    ``corpus_from_docs`` re-ranks ids by frequency afterwards (the
    section-3.2 contract), so the hottest rows end up as the id prefix
    -- exactly the rows the tier makes resident first."""
    ids = np.empty(0, np.int64)
    while ids.size < total_tokens:
        draw = rng.zipf(1.5, size=2 * total_tokens)
        ids = np.concatenate([ids, draw[draw <= vocab] - 1])
    ids = ids[:total_tokens].astype(np.int32)
    lens = rng.integers(128, 256, size=total_tokens // 128 + 1)
    cuts = np.cumsum(lens)
    return [d for d in np.split(ids, cuts[cuts < total_tokens])
            if d.size > 0]


def _child_main(workdir: str, budget: int, vocab: int, topics: int,
                hot: int, blocks: int, sweeps: int,
                total_tokens: int) -> None:
    """The measured process: tiered fit + gauge harvest, one JSON line."""
    from repro import api
    from repro.obs import ObsConfig
    from repro.obs.metrics import load_jsonl

    rng = np.random.default_rng(0)
    docs = _zipf_docs(rng, total_tokens, vocab)
    n_tokens = int(sum(d.size for d in docs))

    peak = {"rss": _rss_bytes()}
    stop = threading.Event()

    def _sample() -> None:
        while not stop.is_set():
            peak["rss"] = max(peak["rss"], _rss_bytes())
            stop.wait(0.05)

    sampler = threading.Thread(target=_sample, daemon=True)
    sampler.start()

    obs_dir = os.path.join(workdir, "obs")
    job = api.LDAJob(
        docs=docs, num_topics=topics, vocab_size=vocab,
        storage="tiered", hot_rows=hot, model_blocks=blocks,
        tier_dir=os.path.join(workdir, "tier"),
        sweeps=sweeps, eval_every=0, seed=0,
        obs=ObsConfig(enabled=True, out_dir=obs_dir, trace=False,
                      metrics=True))
    t0 = time.time()
    api.APSLDA(job).fit()
    dt = time.time() - t0
    stop.set()
    sampler.join(timeout=1.0)

    gauges = {m["name"]: m.get("value")
              for m in load_jsonl(os.path.join(obs_dir, "metrics.jsonl"))
              if m.get("kind") == "gauge"}
    print(json.dumps({
        "tokens": n_tokens * sweeps,
        "seconds": dt,
        "tokens_per_s": n_tokens * sweeps / dt,
        "peak_rss_bytes": peak["rss"],
        "hit_rate": gauges.get("ps.tier.hit_rate"),
        "hot_rows": gauges.get("ps.tier.hot_rows"),
        "tier_device_bytes": gauges.get("ps.tier.device_bytes"),
        "device_table_bytes": gauges.get("exec.tiered.device_table_bytes"),
        "evictions": gauges.get("ps.tier.evictions"),
    }))


def _run_child(workdir: str, budget: int, geom: dict) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_tiered",
         "--child", workdir, "--budget", str(budget),
         "--vocab", str(geom["vocab"]), "--topics", str(geom["topics"]),
         "--hot", str(geom["hot"]), "--blocks", str(geom["blocks"]),
         "--sweeps", str(geom["sweeps"]), "--tokens", str(geom["tokens"])],
        env=env, capture_output=True, text=True, cwd=root)
    if out.returncode != 0:
        sys.stderr.write(out.stdout + out.stderr)
        raise RuntimeError("tiered child failed")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(fast: bool = False) -> None:
    if fast:
        geom = {"vocab": 32768, "topics": 32, "hot": 2048, "blocks": 64,
                "sweeps": 2, "tokens": 96_000}
        budget = MiB // 2
    else:
        geom = {"vocab": 65536, "topics": 64, "hot": 4096, "blocks": 64,
                "sweeps": 3, "tokens": 384_000}
        budget = 2 * MiB
    table_bytes = geom["vocab"] * geom["topics"] * 4
    print(f"tiered,table,{table_bytes / MiB:.1f},MiB,budget,"
          f"{budget / MiB:.2f},MiB,table_over_budget,"
          f"{table_bytes / budget:.1f}x,hot_rows,{geom['hot']}")
    assert table_bytes >= 8 * budget, (table_bytes, budget)

    work = tempfile.mkdtemp(prefix="bench_tiered_")
    try:
        child = _run_child(work, budget, geom)
        dev = child["device_table_bytes"]
        hit = child["hit_rate"]
        print(f"tiered,train,{child['tokens_per_s']:,.0f},tok_per_s,"
              f"peak_rss,{child['peak_rss_bytes'] / MiB:.0f},MiB")
        print(f"tiered,device_table,{dev / MiB:.2f},MiB,"
              f"over_budget,{dev / budget:.2f}x,"
              f"hit_rate,{hit:.3f},evictions,{int(child['evictions'])}")

        os.makedirs(os.path.dirname(OUT), exist_ok=True)
        with open(OUT, "w") as f:
            json.dump({
                "config": dict(geom, budget_bytes=budget,
                               table_bytes=table_bytes),
                "table_over_budget_x": table_bytes / budget,
                "device_table_bytes": dev,
                "device_over_budget_x": dev / budget,
                "hit_rate": hit,
                "evictions": child["evictions"],
                "tokens_per_s": child["tokens_per_s"],
                "peak_rss_bytes": child["peak_rss_bytes"],
            }, f, indent=2)
        print(f"tiered,wrote,{OUT}")

        assert dev is not None and dev <= budget, (
            f"device table {dev} bytes exceeds the {budget} byte budget")
        assert hit is not None and hit >= 0.9, (
            f"tier hit rate {hit} below the 0.9 acceptance bar")
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default="")
    ap.add_argument("--budget", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--topics", type=int, default=32)
    ap.add_argument("--hot", type=int, default=2048)
    ap.add_argument("--blocks", type=int, default=64)
    ap.add_argument("--sweeps", type=int, default=2)
    ap.add_argument("--tokens", type=int, default=96_000)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.child:
        _child_main(args.child, args.budget, args.vocab, args.topics,
                    args.hot, args.blocks, args.sweeps, args.tokens)
    else:
        main(fast=not args.full)
