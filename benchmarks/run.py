"""Benchmark harness -- one module per paper table/figure.

  table1       paper Table 1 (perplexity / runtime / shuffle, size x K sweep)
  loadbalance  paper Figure 5 (cyclic vs blocked request spread)
  convergence  paper Figure 6 (perplexity over time, larger K)
  kernels      Pallas kernels vs refs + O(1)-vs-O(K) sampling cost
  comm         Table 1 shuffle column, from compiled SPMD collectives
  roofline     deliverable (g) report from dry-run artifacts
  infer        serving path: fold-in throughput, batching gain, engine
               latency (emits BENCH_infer.json)
  async        pipelined executor: tokens/sec vs staleness bound, hybrid
               dense/sparse push (emits BENCH_async.json)
  ps           PS client routes: dense vs COO vs hybrid push through
               MatrixHandle.push (emits BENCH_ps.json)
  stream       out-of-core loader: tokens/sec + peak RSS streaming a
               corpus >= 4x the loader budget (emits BENCH_stream.json)
  tiered       tiered parameter storage: train a table >= 8x the device
               budget, gate device bytes + hit rate (emits
               BENCH_tiered.json)
  obs          telemetry plane: disabled-mode overhead bar (<1%) + a
               fully traced train/push/serve demo summarised by
               obs_report (emits BENCH_obs.json)
  serve        production serving plane: concurrent clients through the
               dual-trigger batcher against a live-refreshing service --
               QPS, p50/p95/p99, swaps under load (emits BENCH_serve.json)
  net          network PS: tokens/sec scaling 1 -> 4 worker subprocesses
               against one server under emulated RTT, straggler
               re-assignment on (emits BENCH_net.json)

``python -m benchmarks.run`` runs everything at reduced ("fast") sizes and
prints CSV-ish lines; ``--full`` uses the paper-ladder sizes; ``--only X``
runs one module.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_async, bench_comm, bench_convergence,
                        bench_infer, bench_kernels, bench_loadbalance,
                        bench_net, bench_obs, bench_ps, bench_roofline, bench_serve,
                        bench_stream, bench_table1, bench_tiered)

MODULES = {
    "table1": bench_table1.main,
    "loadbalance": bench_loadbalance.main,
    "convergence": bench_convergence.main,
    "kernels": bench_kernels.main,
    "comm": bench_comm.main,
    "roofline": bench_roofline.main,
    "infer": bench_infer.main,
    "async": bench_async.main,
    "ps": bench_ps.main,
    "stream": bench_stream.main,
    "obs": bench_obs.main,
    "tiered": bench_tiered.main,
    "serve": bench_serve.main,
    "net": bench_net.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full
    names = [args.only] if args.only else list(MODULES)
    failures = []
    for name in names:
        print(f"=== bench:{name} (fast={fast}) ===", flush=True)
        t0 = time.time()
        try:
            MODULES[name](fast=fast)
            print(f"=== bench:{name} done in {time.time()-t0:.1f}s ===",
                  flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print("FAILED benches:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
