"""Network PS scaling: tokens/sec, 1 worker vs an elastic pool of 4.

One embedded ``PSServer`` per arm, real worker subprocesses
(``repro.ps.net.worker``) against it, dynamic lease assignment with one
deliberate straggler (``slow_ms``) in the pool arm -- the re-assignment
policy keeps the slow worker from bounding the run.  The localhost box
has no spare cores, so the pool's win comes from where a distributed
pool's win comes from: **overlapping network round-trips** -- every RPC
carries an emulated RTT (``TransportConfig.delay_ms``), serial for one
worker, hidden by concurrency for four.

Timing starts when the last worker registers (the server's start gate
releases ``acquire`` only then), so subprocess interpreter/jit start-up
skew -- serialised on this box, irrelevant on a real cluster -- stays
out of the tokens/sec numbers.

Gate: >= 1.5x tokens/sec going 1 -> 4 workers.  Writes
``experiments/bench/BENCH_net.json``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

OUT = "experiments/bench/BENCH_net.json"
DELAY_MS = 150.0                 # emulated per-RPC round-trip
STRAGGLER_SLOW_MS = 300.0        # extra per-visit latency for worker 0


def _run_arm(workers: int, *, epochs: int, corp, topics: int,
             shard_tokens: int, block_tokens: int) -> dict:
    import numpy as np

    from repro.api.session import init_stream
    from repro.core import lightlda as lda
    from repro.data import stream as stream_mod
    from repro.ps.client import PSClient
    from repro.ps.net import (NetClient, PSServer, WorkerConfig, WorkerPool,
                              wire)

    sdir = tempfile.mkdtemp(prefix=f"bench-net-{workers}w-")
    meta = stream_mod.write_sharded(sdir, corp, shard_tokens)
    reader = stream_mod.ShardedCorpusReader(sdir)
    cfg = lda.LDAConfig(num_topics=topics, vocab_size=meta.vocab_size,
                        block_tokens=block_tokens, num_shards=1)
    srv = PSServer(meta.vocab_size, topics, stream_dir=sdir).start()
    pool = None
    try:
        nwk0, nk0 = init_stream(reader, cfg, 0,
                                client=PSClient.create(num_shards=1))
        ctl = NetClient.connect(srv.address, name="bench-ctl", role="ctl")
        ctl.push_dense_prefix(wire.MAT_NWK, np.asarray(nwk0.to_dense()))
        ctl.push_dense_prefix(wire.MAT_NK, np.asarray(nk0.value))
        loader = stream_mod.StreamingLoader(reader, seed=0, prefetch=False)
        sched = loader.schedule(stream_mod.Cursor(0, 0), epochs)
        ctl.plan(sched, mode="dynamic", expected_workers=workers)

        base = WorkerConfig(server=srv.address, stream_dir=sdir,
                            num_topics=topics, block_tokens=block_tokens,
                            seed=0, commit_hot_rows=32, delay_ms=DELAY_MS)
        pool = WorkerPool(srv.address, base)
        if workers > 1:
            pool.add_worker(slow_ms=STRAGGLER_SLOW_MS)   # the straggler
            pool.start(workers - 1)
        else:
            pool.start(1)

        # the start gate opens when the last worker says hello -- that is
        # the moment work can begin, so that is t0
        t_spawn = time.time()
        while True:
            st = ctl.status()
            joined = sum(1 for r in st["per_worker"].values()
                         if r["role"] == "worker")
            if joined >= workers:
                break
            if time.time() - t_spawn > 300:
                raise TimeoutError(f"workers never registered: {st}")
            time.sleep(0.05)
        t0 = time.time()
        pool.join(timeout=600)
        elapsed = time.time() - t0

        tokens = meta.num_tokens * epochs
        st = ctl.status()
        per_worker = {r["name"]: r["commits"]
                      for r in st["per_worker"].values()
                      if r["role"] == "worker"}
        return {"workers": workers, "visits": st["leases"]["done"],
                "elapsed_s": elapsed, "tokens": tokens,
                "tokens_per_s": tokens / elapsed,
                "commits_per_worker": per_worker,
                "startup_skew_s": t0 - t_spawn}
    finally:
        if pool is not None:
            pool.close()
        srv.stop()


def main(fast: bool = False):
    from repro.data import corpus as corpus_mod

    epochs = 2 if fast else 3
    corp = corpus_mod.generate_lda_corpus(
        seed=0, num_docs=160 if fast else 320, mean_doc_len=40,
        vocab_size=300, num_topics=6)

    arms = {}
    for n in (1, 4):
        arms[f"w{n}"] = _run_arm(n, epochs=epochs, corp=corp, topics=8,
                                 shard_tokens=1024, block_tokens=512)
        a = arms[f"w{n}"]
        print(f"net,workers={n},tokens_per_s={a['tokens_per_s']:.0f},"
              f"elapsed={a['elapsed_s']:.1f}s,visits={a['visits']},"
              f"commits={a['commits_per_worker']}")

    speedup = arms["w4"]["tokens_per_s"] / arms["w1"]["tokens_per_s"]
    print(f"net,speedup_1_to_4={speedup:.2f},rtt_ms={DELAY_MS:.0f},"
          f"straggler_slow_ms={STRAGGLER_SLOW_MS:.0f}")

    out = {"delay_ms": DELAY_MS, "straggler_slow_ms": STRAGGLER_SLOW_MS,
           "epochs": epochs, "arms": arms, "speedup_1_to_4": speedup}
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"net,artifact,{OUT}")

    assert speedup >= 1.5, \
        f"pool scaling gate: expected >= 1.5x tokens/s 1 -> 4 workers, " \
        f"got {speedup:.2f}x"
    # the straggler must not have been allowed to bound the run: with
    # dynamic assignment it works strictly fewer visits than the median
    commits = arms["w4"]["commits_per_worker"]
    straggler = commits.get("w0", 0)
    others = sorted(v for k, v in commits.items() if k != "w0")
    assert straggler <= others[len(others) // 2], commits
    return out


if __name__ == "__main__":
    main()
