"""Roofline report (deliverable g): reads the dry-run JSONs under
experiments/dryrun/ and prints the per-(arch x shape x mesh) three-term
table for EXPERIMENTS.md section Roofline.  Always writes
``experiments/bench/BENCH_roofline.json`` (status + rows) so CI has a
machine-readable artifact even when no dry-run artifacts exist yet."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "experiments", "dryrun")
OUT = os.path.join(ROOT, "experiments", "bench", "BENCH_roofline.json")


def _write(status: str, rows) -> None:
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"status": status, "rows": rows}, f, indent=2)
    print(f"roofline,wrote,{OUT}")


def load_rows(multi_pod=None):
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if multi_pod is not None and r.get("multi_pod") != multi_pod:
            continue
        rows.append(r)
    return rows


def bottleneck_note(r: dict) -> str:
    """One sentence per pair: what would move the dominant term down."""
    name = r["name"]
    arch = name.split(":")[0]
    shape = name.split(":")[1]
    b = r["bottleneck"]
    moe = arch.startswith(("llama4", "deepseek"))
    ssm = arch.startswith(("mamba2", "hymba"))
    decode = shape in ("decode_32k", "long_500k")
    if b == "collective":
        if moe:
            return ("fuse/overlap the expert all-to-all and ZeRO gathers "
                    "with expert compute (async collectives), or co-locate "
                    "router+experts to cut one hop")
        if shape == "train_4k":
            return ("overlap the dp_model activation re-gathers with the "
                    "next layer's matmuls, or trade activation sharding "
                    "for memory (ACTIVATION_SHARDING='dp')")
        return ("batch the per-layer cache-head gathers or move decode to "
                "a smaller model-parallel degree (more replicas)")
    if b == "memory":
        if decode:
            return ("quantise the KV cache (int8) or shrink it "
                    "architecturally (MLA latent / window ring buffer)")
        if ssm:
            return ("fuse the SSD chunk pipeline into a Pallas kernel so "
                    "L-matrices stay in VMEM instead of round-tripping HBM")
        return ("raise arithmetic intensity: larger per-device batch, "
                "fewer remat recomputes (policy: save attention outputs), "
                "fused flash-attention kernel")
    return ("increase per-device work or reduce MODEL_FLOPS overhead "
            "(remat policy, fused kernels) -- compute-bound is the goal "
            "state")


def main(fast: bool = False):
    rows = load_rows(multi_pod=False)
    if not rows:
        print("roofline,no_dryrun_artifacts,run `python -m repro.launch.dryrun --all` first")
        _write("no_dryrun_artifacts", [])
        return []
    hdr = (f"{'pair':44s}{'bound':>11s}{'t_comp':>10s}{'t_mem':>10s}"
           f"{'t_coll':>10s}{'MF/HF':>7s}{'GiB/dev':>9s}")
    print(hdr)
    for r in sorted(rows, key=lambda r: r["name"]):
        mem = r.get("memory", {})
        gib = (mem.get("temp_size_in_bytes", 0)
               + mem.get("argument_size_in_bytes", 0)) / 2 ** 30
        print(f"{r['name']:44s}{r['bottleneck']:>11s}"
              f"{r['t_compute_s']:10.2e}{r['t_memory_s']:10.2e}"
              f"{r['t_collective_s']:10.2e}{r['useful_flops_ratio']:7.2f}"
              f"{gib:9.2f}")
    print("\nper-pair: what would move the dominant term down")
    for r in sorted(rows, key=lambda r: r["name"]):
        print(f"  {r['name']:44s} [{r['bottleneck']:>10s}] "
              f"{bottleneck_note(r)}")
    _write("ok", sorted(rows, key=lambda r: r["name"]))
    return rows


if __name__ == "__main__":
    main()
