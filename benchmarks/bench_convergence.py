"""Paper Figure 6: perplexity over wall-time for a larger-K LightLDA run
(the paper's 1000-topic ClueWeb12 curve, at CPU scale)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import lightlda as lda
from repro.core import perplexity as ppl
from repro.data import corpus as corpus_mod


def main(fast: bool = False, k: int = 100, sweeps: int = 60):
    if fast:
        k, sweeps = 50, 20
    corp = corpus_mod.generate_lda_corpus(
        seed=0, num_docs=1200 if not fast else 400, mean_doc_len=90,
        vocab_size=4000 if not fast else 1500, num_topics=24)
    cfg = lda.LDAConfig(num_topics=k, vocab_size=corp.vocab_size,
                        block_tokens=8192)
    st = lda.init_state(jax.random.PRNGKey(0), jnp.asarray(corp.w),
                        jnp.asarray(corp.d), corp.num_docs, cfg)
    sweep = jax.jit(lambda s, key: lda.sweep(s, key, cfg))
    sweep(st, jax.random.PRNGKey(9))  # warm compile
    key = jax.random.PRNGKey(1)
    curve = []
    t0 = time.time()
    for i in range(sweeps):
        key, sub = jax.random.split(key)
        st = sweep(st, sub)
        if (i + 1) % max(sweeps // 12, 1) == 0:
            p = float(ppl.training_perplexity(
                st.w, st.d, st.valid, st.ndk, st.nwk.to_dense(),
                st.nk.value, cfg.alpha, cfg.beta))
            el = time.time() - t0
            curve.append({"sweep": i + 1, "elapsed_s": el, "perplexity": p})
            print(f"convergence,K={k},sweep={i+1},t={el:.1f}s,ppl={p:.1f}")
    os.makedirs("experiments/bench", exist_ok=True)
    with open("experiments/bench/convergence.json", "w") as f:
        json.dump(curve, f, indent=2)
    assert curve[-1]["perplexity"] < curve[0]["perplexity"]
    return curve


if __name__ == "__main__":
    main()
