"""Paper Figure 6: perplexity over wall-time for a larger-K LightLDA run
(the paper's 1000-topic ClueWeb12 curve, at CPU scale).

Driven through the unified estimator API's benchmark surface
(``api.Session(job).make_step()``): the compiled executor is warmed once
*before* the timer starts, so the wall-time axis measures sampling only
(comparable with pre-redesign runs), and the curve is sampled on the
same cadence as before.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro import api
from repro.core import perplexity as ppl
from repro.data import corpus as corpus_mod


def main(fast: bool = False, k: int = 100, sweeps: int = 60):
    if fast:
        k, sweeps = 50, 20
    corp = corpus_mod.synthetic_corpus(
        1200 if not fast else 400, 4000 if not fast else 1500,
        true_topics=24, mean_doc_len=90, seed=0)
    job = api.LDAJob(corpus=corp, num_topics=k, block_tokens=8192,
                     sweeps=sweeps, eval_every=0, seed=0)
    sess = api.Session(job, log_fn=lambda *a, **kw: None)
    st, sweep, _ = sess.make_step()
    cfg = sess.cfg
    jax.block_until_ready(sweep(st, jax.random.PRNGKey(9)).z)  # warm compile
    key = jax.random.PRNGKey(1)
    curve = []
    t0 = time.time()
    for i in range(sweeps):
        key, sub = jax.random.split(key)
        st = sweep(st, sub)
        if (i + 1) % max(sweeps // 12, 1) == 0:
            jax.block_until_ready(st.z)
            p = float(ppl.training_perplexity(
                st.w, st.d, st.valid, st.ndk, st.nwk.to_dense(),
                st.nk.value, cfg.alpha, cfg.beta))
            el = time.time() - t0
            curve.append({"sweep": i + 1, "elapsed_s": el, "perplexity": p})
            print(f"convergence,K={k},sweep={i+1},t={el:.1f}s,ppl={p:.1f}")
    os.makedirs("experiments/bench", exist_ok=True)
    with open("experiments/bench/convergence.json", "w") as f:
        json.dump(curve, f, indent=2)
    assert curve[-1]["perplexity"] < curve[0]["perplexity"]
    return curve


if __name__ == "__main__":
    main()
