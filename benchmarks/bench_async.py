"""Asynchronous executor benchmark: tokens/sec vs. staleness bound.

Measures the blocked pipelined executor (train/async_exec.py) on a Zipfian
synthetic corpus at several staleness bounds, against the synchronous
schedule (staleness 0, bitwise-identical to ``lightlda.sweep_blocked_ref``)
as the baseline.  The asynchronous win on a single host comes from the
merge-unit fusion: with ``s`` block deltas allowed in flight, s+1 blocks
sample as one fused step, so the per-block token-cap padding (sized by the
hottest block) averages out and per-step fixed costs amortise.  On a pod
the same schedule additionally hides the pull/push collectives behind
sampling (one psum per group instead of per block).

Also reports the hybrid dense/sparse delta push (``hot_words``) at a few
boundaries.  Writes ``experiments/bench/BENCH_async.json``.

Acceptance bar: best tokens/sec at staleness >= 1 must be >= 1.3x the
synchronous baseline.
"""
from __future__ import annotations

import json
import os

import jax

from repro import api
from repro.data import corpus as corpus_mod
from repro.obs import time_loop
from repro.train import async_exec

OUT = "experiments/bench/BENCH_async.json"


def _setup(num_docs, vocab, k, shards, seed=0):
    """Corpus + initial sampler state, built ONCE through the api session
    and reused for every grid point (state construction is identical
    across exec configs, so rebuilding it per point is pure overhead)."""
    corp = corpus_mod.synthetic_corpus(num_docs, vocab, model_topics=k,
                                       mean_doc_len=60, seed=seed)
    job = api.LDAJob(corpus=corp, num_topics=k, num_shards=shards,
                     sweeps=1, eval_every=0, seed=seed)
    sess = api.Session(job, log_fn=lambda *a, **kw: None)
    state, _, _ = sess.make_step()
    return corp, sess.cfg, state


def _tokens_per_s(state, cfg, exec_cfg, num_tokens, iters, repeats=2):
    """Best-of-``repeats`` throughput of ``iters`` jitted sweeps of the
    executor under ``exec_cfg`` (the layer the api session drives).

    ``time_loop``'s global index matches the old hand-rolled key
    schedule exactly (warmup key 1, repeat r iter i key 2 + r*iters + i).
    """
    step, info = async_exec.make_executor(state, cfg, exec_cfg)
    _, tm = time_loop(
        lambda st, g: step(st, jax.random.PRNGKey(1 + g)), state, iters,
        repeats=repeats, sync=lambda st: st.z, label="async_sweep")
    return tm.best_rate(num_tokens), info


def main(fast: bool = False):
    num_docs, vocab, k, blocks = ((1500, 2000, 50, 16) if fast
                                  else (4000, 8000, 100, 32))
    iters = 3 if fast else 2
    stale_grid = (0, 1, 2, 4, 8) if fast else (0, 1, 2, 4, 8, 16)
    corp, cfg, state = _setup(num_docs, vocab, k, shards=blocks)
    print(f"async,corpus,{corp.num_tokens},tokens,V={vocab},K={k},"
          f"blocks={blocks}")

    results = {}
    for s in stale_grid:
        tps, info = _tokens_per_s(
            state, cfg, async_exec.ExecConfig(staleness=s,
                                              model_blocks=blocks),
            corp.num_tokens, iters)
        results[s] = {"tokens_per_s": tps, "staleness": info["staleness"],
                      "group": info["group"],
                      "token_cap": info["token_cap"]}
        rel = tps / results[0]["tokens_per_s"]
        print(f"async,staleness_{s},group{info['group']},"
              f"cap{info['token_cap']},{tps:,.0f},tok_per_s,x{rel:.2f}")

    base = results[0]["tokens_per_s"]
    best_s = max((s for s in results if s >= 1),
                 key=lambda s: results[s]["tokens_per_s"])
    speedup = results[best_s]["tokens_per_s"] / base
    print(f"async,async_speedup,s{best_s},{speedup:.2f},x_vs_sync")

    # routed push: throughput per PushRoute, keyed by the route's own
    # label (not a stringified hot_words knob), at both the synchronous
    # bound and the best grid point -- so the route choice is not
    # conditioned on one pre-selected staleness.  Each record carries the
    # route's split-vs-apply traffic breakdown (``PushRoute.traffic()``)
    # at the executor's merge-unit batch, the cost table ``ps.autotune``
    # consumes.  Values are identical by construction; this measures
    # traffic-shape cost only.
    from repro import ps as ps_mod
    route_grid = ((None, 256, 0) if fast else (None, 2000, 0))
    batch = results[best_s]["token_cap"] * (results[best_s]["group"] or 1)
    routes = {}
    for h in route_grid:
        route = ps_mod.route_for(h, vocab)
        rec = {"hot_words": h,
               "traffic": {kk: int(vv) for kk, vv in route.traffic(
                   batch, vocab, k).items()},
               "tokens_per_s_by_staleness": {}}
        for s in sorted({0, best_s}):
            tps, _ = _tokens_per_s(
                state, cfg, async_exec.ExecConfig(staleness=s, route=route,
                                                  model_blocks=blocks),
                corp.num_tokens, iters, repeats=1)
            rec["tokens_per_s_by_staleness"][str(s)] = tps
            print(f"async,route_{route.label},s{s},{tps:,.0f},tok_per_s")
        routes[route.label] = rec

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({
            "config": {"tokens": corp.num_tokens, "V": vocab, "K": k,
                       "model_blocks": blocks, "iters": iters},
            "tokens_per_s_by_staleness": {
                str(s): r["tokens_per_s"] for s, r in results.items()},
            "token_cap_by_staleness": {
                str(s): r["token_cap"] for s, r in results.items()},
            "baseline_tokens_per_s": base,
            "best_staleness": best_s,
            "async_speedup_x": speedup,
            "routes": routes,
        }, f, indent=2)
    print(f"async,wrote,{OUT}")
    assert speedup >= 1.3, (
        f"async executor only {speedup:.2f}x the synchronous baseline")


if __name__ == "__main__":
    main(fast=True)
