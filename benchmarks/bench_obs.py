"""Telemetry-plane benchmark: disabled-mode overhead + a fully traced run.

Two halves:

1. **Overhead bar.**  The executor's obs wrapper (``_obs_step``) must be
   free when no session is installed: per sweep it costs one module
   attribute read and one ``is None`` test.  This bench times the wrapped
   step against the unwrapped ``step.raw`` on the same state/keys and
   asserts the overhead is **< 1%** (best-of-repeats on both sides).

2. **Traced demo.**  One obs session covering the whole lifecycle --
   api-session training (exec.sweep / exec.dispatch spans), the eager
   group-schedule replay (``repro.obs.exec_trace``: pull.inflight
   overlapping alias.build/sample/merge.store on separate lanes), one
   ``MatrixHandle.push`` per route (dense / coo / hybrid ps.push spans),
   and a ``QueryEngine`` flush (serve.request_ms p50/p99).  The resulting
   ``trace.json`` is Perfetto-loadable; the bench prints the
   ``obs_report`` summary of the very same directory and asserts every
   section materialised.

Writes ``experiments/bench/BENCH_obs.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, obs, ps
from repro.data import corpus as corpus_mod
from repro.infer.engine import EngineConfig, QueryEngine
from repro.infer.foldin import FoldInConfig
from repro.launch import obs_report
from repro.obs import exec_trace, time_loop
from repro.train import async_exec

OUT = "experiments/bench/BENCH_obs.json"
OBS_DIR = "experiments/bench/obs_demo"


def _setup(num_docs, vocab, k, shards, seed=0):
    corp = corpus_mod.synthetic_corpus(num_docs, vocab, model_topics=k,
                                       mean_doc_len=60, seed=seed)
    job = api.LDAJob(corpus=corp, num_topics=k, num_shards=shards,
                     sweeps=1, eval_every=0, seed=seed)
    sess = api.Session(job, log_fn=lambda *a, **kw: None)
    state, _, _ = sess.make_step()
    return corp, sess.cfg, state


def _ms_per_sweep(step, state, iters, repeats, label):
    _, tm = time_loop(lambda st, g: step(st, jax.random.PRNGKey(1 + g)),
                      state, iters, repeats=repeats, sync=lambda st: st.z,
                      label=label)
    return tm.ms_per_iter()


def main(fast: bool = False):
    num_docs, vocab, k, blocks = ((600, 1000, 32, 8) if fast
                                  else (2000, 4000, 64, 16))
    iters, repeats = (4, 3) if fast else (3, 4)
    corp, cfg, state = _setup(num_docs, vocab, k, shards=blocks)
    print(f"obs,corpus,{corp.num_tokens},tokens,V={vocab},K={k}")

    # --- 1. disabled-mode overhead: wrapped step vs step.raw -------------
    # interleave the two measurements (raw, wrapped, raw, wrapped, ...)
    # and keep the best of each, so clock drift / background load hits
    # both sides equally instead of whichever ran second
    ecfg = async_exec.ExecConfig(staleness=2, model_blocks=blocks)
    step, info = async_exec.make_executor(state, cfg, ecfg)
    assert obs.active() is None, "an obs session is already installed"
    raw_ms = wrapped_ms = float("inf")
    for r in range(repeats):
        raw_ms = min(raw_ms, _ms_per_sweep(step.raw, state, iters, 1,
                                           "obs_raw"))
        wrapped_ms = min(wrapped_ms, _ms_per_sweep(step, state, iters, 1,
                                                   "obs_wrapped"))
    overhead_pct = (wrapped_ms - raw_ms) / raw_ms * 100.0
    print(f"obs,overhead_disabled,{raw_ms:.2f},raw_ms,"
          f"{wrapped_ms:.2f},wrapped_ms,{overhead_pct:+.3f},pct")

    # --- 2. traced demo: one session over train + replay + push + serve --
    obs_cfg = obs.ObsConfig(enabled=True, out_dir=OBS_DIR)
    with obs.session(obs_cfg):
        # training through the api session; ExecConfig.obs=None inherits
        # the installed session, so exec.sweep spans land here
        job = api.LDAJob(corpus=corp, num_topics=k, num_shards=blocks,
                         staleness=2, model_blocks=blocks,
                         sweeps=iters, eval_every=0, seed=0)
        model = api.APSLDA(job, log_fn=lambda *a, **kw: None).fit()

        # eager replay of the same blocked schedule: per-phase spans with
        # pull.inflight on its own lane, visibly overlapping sampling
        exec_trace.traced_pipelined_sweep(
            state, jax.random.PRNGKey(7), cfg, model_blocks=blocks,
            staleness=2)

        # one eager push per route: the per-route ps.push cost table
        client = ps.PSClient.create(num_shards=4)
        base = client.matrix(cfg.V, cfg.K)
        rng = np.random.default_rng(0)
        batch = 4096
        w = jnp.asarray(rng.integers(0, cfg.V, size=batch, dtype=np.int32))
        re = ps.Reassign(
            rows=w, words=w,
            z_old=jnp.asarray(rng.integers(0, k, batch, dtype=np.int32)),
            z_new=jnp.asarray(rng.integers(0, k, batch, dtype=np.int32)),
            changed=jnp.asarray(rng.random(batch) < 0.6))
        for route in (ps.DenseRoute(), ps.CooRoute(),
                      ps.HybridRoute(hot_words=max(cfg.V // 8, 1))):
            base.with_route(route).push(re)

        # serving: engine flush -> serve.request_ms / batch occupancy
        eng = QueryEngine(model.publisher(),
                          EngineConfig(max_batch=16,
                                       foldin=FoldInConfig(num_sweeps=4,
                                                           burnin=2)))
        docs = [rng.integers(0, vocab, size=int(n)).astype(np.int32)
                for n in rng.integers(8, 64, size=24)]
        for d in docs:
            eng.submit(d)
        eng.flush()

    # --- report + acceptance ---------------------------------------------
    report = obs_report.render(OBS_DIR)
    print(report)

    events = obs_report.load_trace(os.path.join(OBS_DIR, "trace.json"))
    names = {ev["name"] for ev in events if ev.get("ph") == "X"}
    for needed in ("exec.sweep", "exec.dispatch", "pull.inflight", "sample",
                   "merge.store", "ps.push", "engine.flush"):
        assert needed in names, f"traced demo missing {needed!r} spans"
    route_labels = {ev["args"]["route"] for ev in events
                    if ev.get("ph") == "X" and ev["name"] == "ps.push"}
    assert {"dense", "coo", "hybrid"} <= route_labels, route_labels
    assert "serve.request_ms" in report, "serving latency section missing"
    print(f"obs,traced_demo,{len(events)},events,"
          f"{sorted(route_labels)},routes")

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({
            "config": {"tokens": corp.num_tokens, "V": vocab, "K": k,
                       "model_blocks": blocks, "iters": iters,
                       "repeats": repeats},
            "raw_ms_per_sweep": raw_ms,
            "wrapped_ms_per_sweep": wrapped_ms,
            "disabled_overhead_pct": overhead_pct,
            "trace_events": len(events),
            "trace_dir": OBS_DIR,
        }, f, indent=2)
    print(f"obs,wrote,{OUT}")
    assert overhead_pct < 1.0, (
        f"disabled-mode obs overhead {overhead_pct:.2f}% >= 1%")


if __name__ == "__main__":
    main(fast=True)
