"""Inference-path benchmark: fold-in latency/throughput + batching gain.

Measures the serving subsystem (repro.infer) against a trained snapshot:

1. snapshot publication cost (the once-per-version alias build);
2. batched fold-in throughput at several batch sizes vs the naive
   one-doc-at-a-time loop (the acceptance bar: batched >= 5x naive);
3. per-request latency of a full engine flush (bucketing + padding).

Writes ``experiments/bench/BENCH_infer.json``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.data import corpus as corpus_mod
from repro.infer.engine import EngineConfig, QueryEngine
from repro.infer.foldin import FoldInConfig, fold_in_batch, pack_docs
from repro.obs import time_loop

OUT = "experiments/bench/BENCH_infer.json"


def _trained_snapshot(num_docs, vocab, k, sweeps, seed=0):
    corp = corpus_mod.synthetic_corpus(num_docs, vocab, model_topics=k,
                                       mean_doc_len=60, seed=seed)
    job = api.LDAJob(corpus=corp, num_topics=k, block_tokens=4096,
                     sweeps=sweeps, eval_every=0, seed=seed)
    model = api.APSLDA(job, log_fn=lambda *a, **kw: None).fit()
    # The once-per-version publish cost, measured honestly in two parts:
    # ``cold`` is the FIRST publish ever for this geometry (pays the jit
    # compile of the cached snapshot builder, once per process), ``steady``
    # is every publish after it -- the recurring cost a live trainer pays
    # per version, and the headline ``snapshot_publish_ms``.
    _, tm_cold = time_loop(lambda c, i: model.publisher(), None, 1,
                           warmup=False, label="snapshot_publish_cold")
    pub, tm = time_loop(lambda c, i: model.publisher(), None, 3,
                        warmup=True, label="snapshot_publish")
    return model.cfg, pub, pub.acquire(), tm.ms_per_iter() / 1e3, \
        tm_cold.best_s


def _foldin_docs_per_s(snap, cfg, fcfg, docs, batch, length, iters=3):
    """Throughput folding ``docs`` through fixed [batch, length] calls."""
    w, valid = pack_docs(docs, length)
    pad = (-len(docs)) % batch
    if pad:
        w = np.pad(w, ((0, pad), (0, 0)))
        valid = np.pad(valid, ((0, pad), (0, 0)))
    w, valid = jnp.asarray(w), jnp.asarray(valid)
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(batch)])

    def run_all():
        outs = []
        for i in range(0, w.shape[0], batch):
            outs.append(fold_in_batch(snap.model, w[i:i + batch],
                                      valid[i:i + batch], keys, cfg, fcfg))
        return jax.block_until_ready(outs)

    _, tm = time_loop(lambda c, i: run_all(), None, iters,
                      label=f"foldin_b{batch}")
    return tm.best_rate(len(docs))


def main(fast: bool = False):
    num_docs, vocab, k, sweeps = ((300, 500, 16, 8) if fast
                                  else (1000, 2000, 50, 20))
    serve_docs, length = (64, 64) if fast else (256, 128)
    cfg, pub, snap, publish_s, publish_cold_s = _trained_snapshot(
        num_docs, vocab, k, sweeps)
    print(f"infer,snapshot_publish,V={cfg.V},K={cfg.K},"
          f"{publish_s*1e3:.1f},ms_steady,{publish_cold_s*1e3:.0f},ms_cold")

    rng = np.random.default_rng(0)
    docs = [rng.integers(0, vocab, size=length - 8).astype(np.int32)
            for _ in range(serve_docs)]
    fcfg = FoldInConfig(num_sweeps=10, burnin=4)

    naive = _foldin_docs_per_s(snap, cfg, fcfg, docs, 1, length)
    print(f"infer,foldin_naive_b1,{naive:.1f},docs_per_s")
    batched = {}
    for b in ((16, 64) if fast else (16, 64, 256)):
        batched[b] = _foldin_docs_per_s(snap, cfg, fcfg, docs, b, length)
        print(f"infer,foldin_batched_b{b},{batched[b]:.1f},docs_per_s")
    best_b = max(batched, key=batched.get)
    speedup = batched[best_b] / naive
    print(f"infer,batching_speedup,b{best_b},{speedup:.1f},x_vs_naive")

    # full engine flush: mixed-length requests through bucketing + padding
    eng = QueryEngine(pub, EngineConfig(max_batch=min(32, serve_docs),
                                        foldin=fcfg))
    mixed = [rng.integers(0, vocab, size=int(n)).astype(np.int32)
             for n in rng.integers(8, length, size=serve_docs)]
    for d in mixed:                        # warm the per-bucket jit cache
        eng.submit(d)
    eng.flush()
    for d in mixed:
        eng.submit(d)
    results, tm = time_loop(lambda c, i: eng.flush(), None, 1,
                            warmup=False, label="engine_flush")
    flush_s = tm.best_s
    print(f"infer,engine_flush,{len(results)}_reqs,"
          f"{flush_s/len(results)*1e3:.2f},ms_per_req")

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({
            "config": {"V": cfg.V, "K": cfg.K, "docs": serve_docs,
                       "doc_len": length, "foldin_sweeps": fcfg.num_sweeps},
            "snapshot_publish_ms": publish_s * 1e3,
            "snapshot_publish_cold_ms": publish_cold_s * 1e3,
            "naive_docs_per_s": naive,
            "batched_docs_per_s": {str(b): v for b, v in batched.items()},
            "batching_speedup_x": speedup,
            "engine_ms_per_request": flush_s / len(results) * 1e3,
        }, f, indent=2)
    print(f"infer,wrote,{OUT}")
    assert speedup >= 5.0, f"batched fold-in only {speedup:.1f}x naive"


if __name__ == "__main__":
    main(fast=True)
