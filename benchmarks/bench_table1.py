"""Paper Table 1: perplexity / runtime / shuffle-write for our LightLDA-PS
vs the Spark EM and Spark Online analogues, sweeping corpus size
(2.5% - 10%) and topic count (20 - 80), at CPU-tractable scale.

Columns mirror the paper:
  size, K, algo, perplexity, runtime_s, shuffle_bytes
Shuffle bytes: LightLDA-PS pushes dense count deltas (no shuffle; we report
the actual per-sweep delta volume), Spark-EM shuffles per-token K-float
messages (GraphX model), Spark-Online shuffles nothing but broadcasts
lambda [K, V] per batch (driver bottleneck -- reported as broadcast bytes).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lda_em as em
from repro.core import lda_online as ov
from repro.core import perplexity as ppl
from repro.data import corpus as corpus_mod

BASE_DOCS = 2400
VOCAB = 2000
TRUE_K = 16
ITERS = 30


def _ppl_counts(w, d, valid, ndk, nwk, nk, alpha, beta):
    return float(ppl.training_perplexity(w, d, valid, ndk, nwk, nk,
                                         alpha, beta))


def run_lightlda(corp, k, iters=ITERS):
    from repro import api

    job = api.LDAJob(corpus=corp, num_topics=k, block_tokens=8192,
                     sweeps=iters, eval_every=0, seed=0)
    st, sweep, _ = api.Session(job, log_fn=lambda *a, **kw: None).make_step()
    cfg = job.lda_config(corp.vocab_size)
    sweep(st, jax.random.PRNGKey(1))  # compile outside the timer
    key = jax.random.PRNGKey(2)
    t0 = time.time()
    for _ in range(iters):
        key, sub = jax.random.split(key)
        st = sweep(st, sub)
    jax.block_until_ready(st.z)
    rt = time.time() - t0
    p = _ppl_counts(st.w, st.d, st.valid, st.ndk, st.nwk.to_dense(),
                    st.nk.value, cfg.alpha, cfg.beta)
    # per-sweep push volume: one dense [V, K] int32 delta per worker flush
    shuffle = corp.vocab_size * k * 4
    return p, rt, shuffle


def run_em(corp, k, iters=ITERS):
    cfg = em.EMConfig(num_topics=k, vocab_size=corp.vocab_size)
    w, d = jnp.asarray(corp.w), jnp.asarray(corp.d)
    valid = jnp.ones(corp.num_tokens, bool)
    st = em.init_state(jax.random.PRNGKey(0), w, d, valid, corp.num_docs, cfg)
    step = jax.jit(lambda s: em.em_iteration(s, w, d, valid, corp.num_docs,
                                             cfg))
    step(st)
    t0 = time.time()
    for _ in range(iters):
        st = step(st)
    jax.block_until_ready(st.nk)
    rt = time.time() - t0
    p = _ppl_counts(w, d, valid, st.ndk, st.nwk, st.nk, cfg.alpha, cfg.beta)
    return p, rt, em.shuffle_bytes_per_iter(corp.num_tokens, cfg)


def run_online(corp, k, iters=ITERS):
    cfg = ov.OnlineConfig(num_topics=k, vocab_size=corp.vocab_size,
                          batch_docs=128)
    st = ov.init_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    w, d = jnp.asarray(corp.w), jnp.asarray(corp.d)
    valid = jnp.ones(corp.num_tokens, bool)
    # pre-densify minibatches (pipeline work, off the clock like Spark's
    # RDD cache)
    batches = []
    for _ in range(iters):
        docs = rng.choice(corp.num_docs, cfg.batch_docs, replace=False)
        batches.append(jnp.asarray(corpus_mod.doc_term_matrix(corp, docs)))
    mask = jnp.ones(cfg.batch_docs)
    step = jax.jit(lambda s, dw: ov.online_step(s, dw, mask,
                                                corp.num_docs, cfg))
    step(st, batches[0])
    t0 = time.time()
    for dw in batches:
        st = step(st, dw)
    jax.block_until_ready(st.lam)
    rt = time.time() - t0
    phi = ov.phi_from_state(st)
    theta = ppl.fold_in_theta(w, d, valid, phi, corp.num_docs, cfg.alpha)
    ll = ppl.log_likelihood(w, d, valid, theta, phi, corp.num_docs)
    p = float(jnp.exp(-ll / corp.num_tokens))
    broadcast = k * corp.vocab_size * 4  # lambda broadcast per batch
    return p, rt, broadcast


def main(fast: bool = False):
    big = corpus_mod.synthetic_corpus(BASE_DOCS, VOCAB, true_topics=TRUE_K,
                                      mean_doc_len=80, seed=0)
    rows = []
    sizes = [0.25, 0.5, 0.75, 1.0]       # the paper's 2.5/5/7.5/10% ladder
    ks = [20] if fast else [20, 40, 60, 80]
    size_list = sizes[:2] if fast else sizes
    for frac in size_list:
        corp = big.subset(frac) if frac < 1.0 else big
        for k in ([20] if frac < 1.0 else ks):
            for name, fn in (("lightlda-ps", run_lightlda),
                             ("spark-em", run_em),
                             ("spark-online", run_online)):
                p, rt, sh = fn(corp, k)
                rows.append(dict(size=frac, K=k, algo=name, perplexity=p,
                                 runtime_s=rt, shuffle_bytes=sh,
                                 tokens=corp.num_tokens))
                print(f"table1,size={frac},K={k},{name},"
                      f"ppl={p:.1f},runtime={rt:.2f}s,comm={sh/1e6:.1f}MB")
    return rows


if __name__ == "__main__":
    main()
