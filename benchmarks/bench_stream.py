"""Out-of-core streaming benchmark: tokens/sec + peak host RSS.

The claim under test is the tentpole's: a corpus far larger than the
loader's memory budget streams through the double-buffered loader
(data/stream.py) without the process ever holding more than the budget.
The measured phase runs in a **subprocess** so its ``ru_maxrss``
high-water mark is clean -- not polluted by the JAX runtime or by other
benchmark modules that ran earlier in the parent -- and the module
deliberately imports no jax so the child stays a pure numpy data plane.

Protocol (fast mode):
  * write a synthetic Zipf-ish corpus of >= 4x the loader budget to a
    temp dir, shard by shard (the writer itself is bounded-memory);
  * child process: one full epoch through ``StreamingLoader`` with the
    budget enforced, reporting tokens/sec and its peak RSS;
  * assert peak RSS < 2x budget (the acceptance bar) and corpus >= 4x.

Also reports a small in-process *training* throughput number (the
stream trainer end to end at toy scale -- this one does use jax).
Writes ``experiments/bench/BENCH_stream.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.data import stream as stream_mod

OUT = "experiments/bench/BENCH_stream.json"
MiB = 2 ** 20


def _write_synthetic(path: str, total_tokens: int, vocab: int,
                     tokens_per_shard: int, seed: int = 0) -> "stream_mod.StreamMeta":
    """Zipf-ish corpus written with bounded memory via the bulk API."""
    rng = np.random.default_rng(seed)
    writer = stream_mod.ShardedCorpusWriter(
        path, vocab, tokens_per_shard,
        doc_cap=max(64, tokens_per_shard // 64))
    remaining = total_tokens
    chunk_docs = 4096
    while remaining > 0:
        lens = rng.integers(64, 192, size=chunk_docs).astype(np.int64)
        cum = np.cumsum(lens)
        cut = int(np.searchsorted(cum, remaining, "right"))
        if cut == 0:
            lens = np.array([remaining], np.int64)
        else:
            lens = lens[:cut]
        n = int(lens.sum())
        # power-law-ish marginal: rank ~ u^gamma concentrates the head
        w = (vocab * rng.random(n) ** 3.5).astype(np.int32)
        writer.add_tokens(np.minimum(w, vocab - 1), lens)
        remaining -= n
    return writer.close()


def _rss_bytes() -> int:
    """Current resident set from /proc (Linux).  Deliberately *not*
    ``ru_maxrss``: that high-water mark is inherited across ``fork`` from
    the parent (whose jax runtime would be billed to us), and some
    sandbox kernels omit VmHWM entirely.  The loader's footprint is
    steady-state (two buffered shards), so sampling VmRSS once per shard
    captures the true peak."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def _child_main(path: str, budget: int, epochs: int) -> None:
    """The measured process: stream the corpus, print one JSON line."""
    reader = stream_mod.ShardedCorpusReader(path)
    loader = stream_mod.StreamingLoader(reader, seed=0,
                                        memory_budget=budget, load_z=False)
    tokens = 0
    checksum = 0
    peak_rss = _rss_bytes()
    t0 = time.time()
    for cur, sid, shard in loader.iterate(stream_mod.Cursor(0, 0), epochs):
        tokens += shard.n_tokens
        checksum ^= int(shard.w[shard.n_tokens - 1]) ^ int(
            shard.w[: shard.n_tokens].max())
        peak_rss = max(peak_rss, _rss_bytes())
    dt = time.time() - t0
    print(json.dumps({"tokens": tokens, "seconds": dt,
                      "tokens_per_s": tokens / dt,
                      "peak_rss_bytes": peak_rss,
                      "checksum": checksum}))


def _run_child(path: str, budget: int, epochs: int) -> dict:
    src = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(
        stream_mod.__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    # the measured process is a pure data plane: BLAS thread pools would
    # only inflate its RSS baseline (numpy import alone costs hundreds of
    # MiB of ru_maxrss on many-core hosts otherwise)
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS",
                "MKL_NUM_THREADS"):
        env[var] = "1"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_stream", "--child", path,
         "--budget", str(budget), "--epochs", str(epochs)],
        env=env, capture_output=True, text=True, check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    return json.loads(out.stdout.strip().splitlines()[-1])


def _train_smoke() -> dict:
    """Tiny end-to-end stream-training throughput (uses jax; in-process)."""
    import jax  # noqa: F401  (deferred: the child must never see this)
    from repro import api
    from repro.data import corpus as corpus_mod

    work = tempfile.mkdtemp(prefix="bench_stream_train_")
    try:
        corp = corpus_mod.synthetic_corpus(800, 2000, true_topics=10,
                                           mean_doc_len=60, seed=0)
        stream_mod.write_sharded(os.path.join(work, "s"), corp,
                                 tokens_per_shard=8192)
        job = api.LDAJob(stream_dir=os.path.join(work, "s"),
                         num_topics=20, block_tokens=2048, num_shards=4,
                         staleness=1, epochs=2, seed=0, eval_every=0)
        t0 = time.time()
        api.Session(job, log_fn=lambda *a, **kw: None).run()
        dt = time.time() - t0
        return {"tokens": 2 * corp.num_tokens, "seconds": dt,
                "tokens_per_s": 2 * corp.num_tokens / dt}
    finally:
        shutil.rmtree(work, ignore_errors=True)


def main(fast: bool = False) -> None:
    budget = (128 if fast else 256) * MiB
    tokens_per_shard = 4 * MiB        # 4M tokens -> 32 MiB on disk (w+d)
    bytes_per_token = 8               # w + d int32 (no z: load_z=False)
    target_bytes = 4 * budget
    total_tokens = -(-target_bytes // bytes_per_token)
    vocab = 100_000

    work = tempfile.mkdtemp(prefix="bench_stream_")
    path = os.path.join(work, "corpus")
    try:
        t0 = time.time()
        meta = _write_synthetic(path, total_tokens, vocab, tokens_per_shard)
        write_s = time.time() - t0
        corpus_bytes = meta.num_shards * (
            meta.tokens_per_shard * bytes_per_token + meta.doc_cap * 8)
        print(f"stream,corpus,{meta.num_tokens},tokens,"
              f"{corpus_bytes / MiB:.0f},MiB,{meta.num_shards},shards,"
              f"wrote_in,{write_s:.1f}s")
        print(f"stream,budget,{budget / MiB:.0f},MiB,corpus_over_budget,"
              f"{corpus_bytes / budget:.1f}x")
        assert corpus_bytes >= 4 * budget, (corpus_bytes, budget)

        child = _run_child(path, budget, epochs=1)
        rss = child["peak_rss_bytes"]
        print(f"stream,loader,{child['tokens_per_s']:,.0f},tok_per_s,"
              f"peak_rss,{rss / MiB:.0f},MiB,"
              f"rss_over_budget,{rss / budget:.2f}x")

        train = _train_smoke()
        print(f"stream,train_smoke,{train['tokens_per_s']:,.0f},tok_per_s")

        os.makedirs(os.path.dirname(OUT), exist_ok=True)
        with open(OUT, "w") as f:
            json.dump({
                "config": {"budget_bytes": budget, "vocab": vocab,
                           "tokens_per_shard": tokens_per_shard,
                           "num_shards": meta.num_shards,
                           "corpus_bytes": corpus_bytes,
                           "corpus_tokens": meta.num_tokens},
                "write_seconds": write_s,
                "loader_tokens_per_s": child["tokens_per_s"],
                "peak_rss_bytes": rss,
                "rss_over_budget_x": rss / budget,
                "corpus_over_budget_x": corpus_bytes / budget,
                "train_smoke_tokens_per_s": train["tokens_per_s"],
            }, f, indent=2)
        print(f"stream,wrote,{OUT}")
        assert rss < 2 * budget, (
            f"peak RSS {rss / MiB:.0f} MiB exceeds 2x the "
            f"{budget / MiB:.0f} MiB loader budget")
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default="")
    ap.add_argument("--budget", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.child:
        _child_main(args.child, args.budget, args.epochs)
    else:
        main(fast=not args.full)
