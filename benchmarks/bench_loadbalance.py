"""Paper Figure 5: expected proportion of parameter-server requests per
machine (30 machines) under {ordered, shuffled} x {cyclic, blocked}
partitioning, computed from corpus token counts.  Reports the max/mean
spread per scheme; cyclic+ordered wins, and with the hot-word dense buffer
(section 3.3) it is near-uniform."""
from __future__ import annotations

import numpy as np

from repro.core.pserver import CyclicLayout
from repro.data import corpus as corpus_mod

MACHINES = 30


def request_spread(freq: np.ndarray, assignment: np.ndarray) -> float:
    load = np.bincount(assignment, weights=freq, minlength=MACHINES)
    return float(load.max() / load.mean())


def main(fast: bool = False):
    corp = corpus_mod.synthetic_corpus(600 if fast else 1500, 3000,
                                       true_topics=16, mean_doc_len=80,
                                       seed=0)
    freq = corp.word_freq.astype(float)     # frequency-ordered (rank 0 hot)
    v = len(freq)
    lay = CyclicLayout(v, MACHINES)
    rng = np.random.default_rng(0)

    phys = np.asarray(lay.to_physical(np.arange(v)))
    cyc_assign = phys // lay.rows_per_shard
    blk_assign = np.arange(v) * MACHINES // ((v // MACHINES + 1) * MACHINES)
    blk_assign = np.minimum(np.arange(v) // (v // MACHINES + (v % MACHINES > 0)),
                            MACHINES - 1)
    shuffle = rng.permutation(v)

    rows = {}
    rows["cyclic_ordered"] = request_spread(freq, cyc_assign)
    rows["cyclic_shuffled"] = request_spread(freq[shuffle], cyc_assign)
    rows["blocked_ordered"] = request_spread(freq, blk_assign)
    # hot-word buffer: top 2% of words aggregated locally, flushed once
    capped = freq.copy()
    hot = max(v // 50, 1)
    capped[:hot] = freq[hot]
    rows["cyclic_ordered_hotbuf"] = request_spread(capped, cyc_assign)

    for name, spread in rows.items():
        print(f"loadbalance,{name},max_over_mean={spread:.3f}")

    assert rows["cyclic_ordered"] < rows["blocked_ordered"]
    assert rows["cyclic_ordered_hotbuf"] < 1.1
    return rows


if __name__ == "__main__":
    main()
