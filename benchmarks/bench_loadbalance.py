"""Load balance: paper Figure 5 spread + the elastic-pool straggler drill.

Part 1 (paper Figure 5): expected proportion of parameter-server requests
per machine (30 machines) under {ordered, shuffled} x {cyclic, blocked}
partitioning, computed from corpus token counts.  Reports the max/mean
spread per scheme; cyclic+ordered wins, and with the hot-word dense
buffer (section 3.3) it is near-uniform.

Part 2 (straggler scenario, DESIGN.md section 15): an event-driven
simulation drives the *real* ``ShardLeaseBook`` state machine with one
worker slowed 4x and measures the schedule makespan under each
assignment policy:

  * ``static``        -- visits pre-partitioned, no re-assignment: the
                         straggler's backlog bounds the run (baseline);
  * ``static_steal``  -- idle workers steal the straggler's unstarted
                         visits;
  * ``dynamic``       -- one global queue (stragglers naturally pull
                         fewer visits).

The gate: re-assignment (steal or dynamic) must beat the static
baseline by >= 1.3x.  Writes ``experiments/bench/BENCH_loadbalance.json``.
"""
from __future__ import annotations

import heapq
import json
import os

import numpy as np

from repro.core.pserver import CyclicLayout
from repro.data import corpus as corpus_mod
from repro.data.leases import ShardLeaseBook

MACHINES = 30
OUT = "experiments/bench/BENCH_loadbalance.json"


def request_spread(freq: np.ndarray, assignment: np.ndarray) -> float:
    load = np.bincount(assignment, weights=freq, minlength=MACHINES)
    return float(load.max() / load.mean())


# ---------------------------------------------------------------------------
# part 2: straggler makespan over the real lease state machine
# ---------------------------------------------------------------------------

def simulate_straggler(mode: str, *, workers: int = 4, shards: int = 16,
                       epochs: int = 3, slow_factor: float = 4.0,
                       visit_cost: float = 1.0) -> dict:
    """Event-driven makespan of one schedule under ``mode``.

    Worker 0 is the straggler (``slow_factor`` x per visit).  Each
    worker repeatedly acquires from the shared ``ShardLeaseBook`` --
    exactly the server's grant path -- and completes ``visit_cost``
    (scaled) time units later; a worker that must wait re-polls when the
    next completion fires.  Returns makespan + per-worker visit counts.
    """
    sched = [(e, e * shards + s, s) for e in range(epochs)
             for s in range(shards)]
    book = ShardLeaseBook(sched, mode=mode,
                          slots=workers if mode != "dynamic" else 0)
    cost = [visit_cost * (slow_factor if w == 0 else 1.0)
            for w in range(workers)]
    visits = [0] * workers
    held = [None] * workers              # lease a busy worker will finish
    busy_until: dict = {}                # worker -> completion time
    ready = [(0.0, w) for w in range(workers)]
    heapq.heapify(ready)
    makespan = 0.0
    guard = 0
    while ready:
        guard += 1
        assert guard < 100000, "simulation did not converge"
        now, w = heapq.heappop(ready)
        if held[w] is not None:          # this wake IS the completion
            book.complete(held[w])
            held[w] = None
            busy_until.pop(w, None)
            visits[w] += 1
            makespan = max(makespan, now)
        st, lease = book.acquire(w, slot=w)
        if st == "done":
            continue                     # worker retires
        if st == "wait":
            # shard-locked or slot drained: the book only changes when a
            # busy worker finishes -- sleep until the next completion
            # (>=: an equal-time completion may not have fired yet)
            nxt = min((t for t in busy_until.values() if t >= now),
                      default=None)
            assert nxt is not None, f"deadlock: {book.stats()}"
            heapq.heappush(ready, (nxt + 1e-9, w))
            continue
        held[w] = lease.lease_id
        busy_until[w] = now + cost[w]
        heapq.heappush(ready, (busy_until[w], w))
    assert book.all_done(), book.stats()
    return {"mode": mode, "makespan": makespan, "visits": visits,
            "stolen": book.stolen}


def straggler_scenario(fast: bool) -> dict:
    kw = dict(workers=4, shards=8 if fast else 16,
              epochs=2 if fast else 4, slow_factor=4.0)
    rows = {m: simulate_straggler(m, **kw)
            for m in ("static", "static_steal", "dynamic")}
    base = rows["static"]["makespan"]
    for m, r in rows.items():
        r["speedup_vs_static"] = base / r["makespan"]
        print(f"loadbalance,straggler_{m},makespan={r['makespan']:.2f},"
              f"speedup={r['speedup_vs_static']:.2f},"
              f"straggler_visits={r['visits'][0]},stolen={r['stolen']}")
    return rows


def main(fast: bool = False):
    corp = corpus_mod.synthetic_corpus(600 if fast else 1500, 3000,
                                       true_topics=16, mean_doc_len=80,
                                       seed=0)
    freq = corp.word_freq.astype(float)     # frequency-ordered (rank 0 hot)
    v = len(freq)
    lay = CyclicLayout(v, MACHINES)
    rng = np.random.default_rng(0)

    phys = np.asarray(lay.to_physical(np.arange(v)))
    cyc_assign = phys // lay.rows_per_shard
    blk_assign = np.arange(v) * MACHINES // ((v // MACHINES + 1) * MACHINES)
    blk_assign = np.minimum(np.arange(v) // (v // MACHINES + (v % MACHINES > 0)),
                            MACHINES - 1)
    shuffle = rng.permutation(v)

    rows = {}
    rows["cyclic_ordered"] = request_spread(freq, cyc_assign)
    rows["cyclic_shuffled"] = request_spread(freq[shuffle], cyc_assign)
    rows["blocked_ordered"] = request_spread(freq, blk_assign)
    # hot-word buffer: top 2% of words aggregated locally, flushed once
    capped = freq.copy()
    hot = max(v // 50, 1)
    capped[:hot] = freq[hot]
    rows["cyclic_ordered_hotbuf"] = request_spread(capped, cyc_assign)

    for name, spread in rows.items():
        print(f"loadbalance,{name},max_over_mean={spread:.3f}")

    assert rows["cyclic_ordered"] < rows["blocked_ordered"]
    assert rows["cyclic_ordered_hotbuf"] < 1.1

    straggler = straggler_scenario(fast)
    # the point of re-assignment: both policies must beat no-re-assignment
    assert straggler["static_steal"]["speedup_vs_static"] >= 1.3, straggler
    assert straggler["dynamic"]["speedup_vs_static"] >= 1.3, straggler
    # and the steal counter proves the mechanism (not just luck)
    assert straggler["static_steal"]["stolen"] >= 1, straggler

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump({"request_spread": rows, "straggler": straggler}, f,
                  indent=2)
    print(f"loadbalance,artifact,{OUT}")
    return {"request_spread": rows, "straggler": straggler}


if __name__ == "__main__":
    main()
